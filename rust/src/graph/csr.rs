//! Undirected graphs and CSR sparse matrices.

use crate::tensor::Matrix;

/// An undirected, unweighted graph stored as a symmetric adjacency list.
#[derive(Clone, Debug)]
pub struct Graph {
    n: usize,
    /// Canonical edge list (u < v), deduplicated, sorted.
    edges: Vec<(u32, u32)>,
    /// adj[u] = sorted neighbors of u.
    adj: Vec<Vec<u32>>,
}

impl Graph {
    /// Build from an edge list. Self-loops are dropped, duplicates merged,
    /// direction ignored.
    pub fn from_edges(n: usize, edges: &[(usize, usize)]) -> Graph {
        let mut canon: Vec<(u32, u32)> = edges
            .iter()
            .filter(|&&(u, v)| u != v)
            .map(|&(u, v)| {
                assert!(u < n && v < n, "edge ({u},{v}) out of range n={n}");
                if u < v {
                    (u as u32, v as u32)
                } else {
                    (v as u32, u as u32)
                }
            })
            .collect();
        canon.sort_unstable();
        canon.dedup();
        let mut adj = vec![Vec::new(); n];
        for &(u, v) in &canon {
            adj[u as usize].push(v);
            adj[v as usize].push(u);
        }
        for a in &mut adj {
            a.sort_unstable();
        }
        Graph {
            n,
            edges: canon,
            adj,
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }
    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }
    pub fn edges(&self) -> &[(u32, u32)] {
        &self.edges
    }
    pub fn degree(&self, u: usize) -> usize {
        self.adj[u].len()
    }
    pub fn neighbors(&self, u: usize) -> &[u32] {
        &self.adj[u]
    }
    pub fn avg_degree(&self) -> f64 {
        2.0 * self.num_edges() as f64 / self.n.max(1) as f64
    }

    pub fn has_edge(&self, u: usize, v: usize) -> bool {
        self.adj[u].binary_search(&(v as u32)).is_ok()
    }

    /// The GCN-normalised adjacency with self-loops:
    /// `Ã = (D+I)^{-1/2} (A+I) (D+I)^{-1/2}` (paper, Problem 1).
    /// Symmetric by construction.
    pub fn normalized_adjacency(&self) -> Csr {
        let inv_sqrt: Vec<f32> = (0..self.n)
            .map(|u| 1.0 / ((self.degree(u) + 1) as f32).sqrt())
            .collect();
        let mut rows = Vec::with_capacity(self.n);
        for u in 0..self.n {
            // Sorted col insertion: neighbors are sorted; weave in diagonal.
            let mut cols = Vec::with_capacity(self.adj[u].len() + 1);
            let mut vals = Vec::with_capacity(self.adj[u].len() + 1);
            let mut placed_diag = false;
            for &v in &self.adj[u] {
                if !placed_diag && (v as usize) > u {
                    cols.push(u as u32);
                    vals.push(inv_sqrt[u] * inv_sqrt[u]);
                    placed_diag = true;
                }
                cols.push(v);
                vals.push(inv_sqrt[u] * inv_sqrt[v as usize]);
            }
            if !placed_diag {
                cols.push(u as u32);
                vals.push(inv_sqrt[u] * inv_sqrt[u]);
            }
            rows.push((cols, vals));
        }
        Csr::from_rows(self.n, rows)
    }
}

/// Compressed-sparse-row f32 matrix (possibly rectangular — community
/// blocks `Ã_{m,r}` are n_m × n_r).
///
/// **Capacity ceiling:** `row_ptr` (and `col_idx`) use `u32`, so a `Csr`
/// holds at most `u32::MAX` (≈ 4.29 billion) nonzeros — ~34 GB of
/// col/val payload, far beyond any current in-memory workload here.
/// Constructors enforce the ceiling with a checked conversion
/// (`checked_ptr_u32`) instead of silently truncating.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    nrows: usize,
    ncols: usize,
    row_ptr: Vec<u32>,
    col_idx: Vec<u32>,
    vals: Vec<f32>,
}

/// Checked `usize → u32` conversion for CSR row pointers. Panics with a
/// clear message instead of silently truncating past 2³² nonzeros.
#[inline]
fn checked_ptr_u32(nnz: usize) -> u32 {
    u32::try_from(nnz).unwrap_or_else(|_| {
        panic!("Csr nnz {nnz} exceeds the u32 row_ptr ceiling ({})", u32::MAX)
    })
}

impl Csr {
    /// Build from per-row (cols, vals); cols must be sorted & in range.
    pub fn from_rows(ncols: usize, rows: Vec<(Vec<u32>, Vec<f32>)>) -> Csr {
        let nrows = rows.len();
        let nnz: usize = rows.iter().map(|(c, _)| c.len()).sum();
        let mut row_ptr = Vec::with_capacity(nrows + 1);
        let mut col_idx = Vec::with_capacity(nnz);
        let mut vals = Vec::with_capacity(nnz);
        row_ptr.push(0u32);
        for (cols, v) in rows {
            assert_eq!(cols.len(), v.len());
            debug_assert!(cols.windows(2).all(|w| w[0] < w[1]), "unsorted row");
            debug_assert!(cols.iter().all(|&c| (c as usize) < ncols));
            col_idx.extend_from_slice(&cols);
            vals.extend_from_slice(&v);
            row_ptr.push(checked_ptr_u32(col_idx.len()));
        }
        Csr {
            nrows,
            ncols,
            row_ptr,
            col_idx,
            vals,
        }
    }

    /// Build from (row, col, val) triplets (need not be sorted; duplicates
    /// summed).
    pub fn from_triplets(
        nrows: usize,
        ncols: usize,
        triplets: &[(usize, usize, f32)],
    ) -> Csr {
        let mut per_row: Vec<Vec<(u32, f32)>> = vec![Vec::new(); nrows];
        for &(r, c, v) in triplets {
            assert!(r < nrows && c < ncols);
            per_row[r].push((c as u32, v));
        }
        let rows = per_row
            .into_iter()
            .map(|mut row| {
                row.sort_unstable_by_key(|&(c, _)| c);
                let mut cols = Vec::with_capacity(row.len());
                let mut vals: Vec<f32> = Vec::with_capacity(row.len());
                for (c, v) in row {
                    if cols.last() == Some(&c) {
                        *vals.last_mut().unwrap() += v;
                    } else {
                        cols.push(c);
                        vals.push(v);
                    }
                }
                (cols, vals)
            })
            .collect();
        Csr::from_rows(ncols, rows)
    }

    pub fn nrows(&self) -> usize {
        self.nrows
    }
    pub fn ncols(&self) -> usize {
        self.ncols
    }
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    pub fn row(&self, r: usize) -> (&[u32], &[f32]) {
        let lo = self.row_ptr[r] as usize;
        let hi = self.row_ptr[r + 1] as usize;
        (&self.col_idx[lo..hi], &self.vals[lo..hi])
    }

    /// The row range `lo..hi` as its own CSR (same `ncols`). Each kept
    /// row's (cols, vals) slices are copied verbatim, so any per-row
    /// kernel (SpMM in particular) produces bitwise-identical values for
    /// the sliced rows — the property the inference activation cache
    /// relies on to warm one community at a time.
    pub fn slice_rows(&self, lo: usize, hi: usize) -> Csr {
        assert!(lo <= hi && hi <= self.nrows, "slice_rows out of range");
        let plo = self.row_ptr[lo] as usize;
        let phi = self.row_ptr[hi] as usize;
        Csr {
            nrows: hi - lo,
            ncols: self.ncols,
            row_ptr: self.row_ptr[lo..=hi].iter().map(|&p| p - plo as u32).collect(),
            col_idx: self.col_idx[plo..phi].to_vec(),
            vals: self.vals[plo..phi].to_vec(),
        }
    }

    pub fn get(&self, r: usize, c: usize) -> f32 {
        let (cols, vals) = self.row(r);
        match cols.binary_search(&(c as u32)) {
            Ok(i) => vals[i],
            Err(_) => 0.0,
        }
    }

    /// Verify symmetry (requires square). Used by tests and to justify the
    /// `Ã^T = Ã` optimisation in the coordinator.
    pub fn is_symmetric(&self, tol: f32) -> bool {
        if self.nrows != self.ncols {
            return false;
        }
        for r in 0..self.nrows {
            let (cols, vals) = self.row(r);
            for (&c, &v) in cols.iter().zip(vals) {
                if (self.get(c as usize, r) - v).abs() > tol {
                    return false;
                }
            }
        }
        true
    }

    /// Transpose (O(nnz)); needed for rectangular blocks `Ã_{r,m} = Ã_{m,r}^T`.
    pub fn transpose(&self) -> Csr {
        // The prefix-sum below accumulates in u32; guard the total the
        // same way `from_rows` does (it is an invariant of `self`, but a
        // cheap check keeps the truncation impossible by construction).
        let _ = checked_ptr_u32(self.nnz());
        let mut counts = vec![0u32; self.ncols + 1];
        for &c in &self.col_idx {
            counts[c as usize + 1] += 1;
        }
        for i in 1..counts.len() {
            counts[i] += counts[i - 1];
        }
        let row_ptr = counts.clone();
        let mut col_idx = vec![0u32; self.nnz()];
        let mut vals = vec![0.0f32; self.nnz()];
        let mut next = counts;
        for r in 0..self.nrows {
            let (cols, v) = self.row(r);
            for (&c, &x) in cols.iter().zip(v) {
                let slot = next[c as usize] as usize;
                col_idx[slot] = r as u32;
                vals[slot] = x;
                next[c as usize] += 1;
            }
        }
        Csr {
            nrows: self.ncols,
            ncols: self.nrows,
            row_ptr,
            col_idx,
            vals,
        }
    }

    /// Sparse × dense: `out = self @ x` where x is (ncols × k) dense.
    /// This is the L3 hot path (profiled + optimised in the perf pass):
    /// row-major accumulation so each nonzero streams a contiguous slice.
    pub fn spmm(&self, x: &Matrix) -> Matrix {
        assert_eq!(
            self.ncols,
            x.rows(),
            "spmm shape mismatch: {}x{} @ {}x{}",
            self.nrows,
            self.ncols,
            x.rows(),
            x.cols()
        );
        let k = x.cols();
        let mut out = Matrix::zeros(self.nrows, k);
        let xd = x.data();
        let od = out.data_mut();
        for r in 0..self.nrows {
            let lo = self.row_ptr[r] as usize;
            let hi = self.row_ptr[r + 1] as usize;
            let orow = &mut od[r * k..(r + 1) * k];
            for i in lo..hi {
                let c = self.col_idx[i] as usize;
                let v = self.vals[i];
                let xrow = &xd[c * k..(c + 1) * k];
                // Vectorisable axpy over contiguous rows.
                for (o, &xv) in orow.iter_mut().zip(xrow) {
                    *o += v * xv;
                }
            }
        }
        out
    }

    /// Number of distinct columns with at least one nonzero (the boundary
    /// size when this is a cross-community block).
    pub fn distinct_cols(&self) -> usize {
        let mut seen = vec![false; self.ncols];
        for &c in &self.col_idx {
            seen[c as usize] = true;
        }
        seen.iter().filter(|&&s| s).count()
    }

    /// Zero-pad to a larger shape (extra rows empty, extra cols unused).
    /// Used to lift community blocks to the padded artifact shapes.
    pub fn pad_to(&self, nrows: usize, ncols: usize) -> Csr {
        assert!(nrows >= self.nrows && ncols >= self.ncols);
        let mut row_ptr = self.row_ptr.clone();
        row_ptr.resize(nrows + 1, *self.row_ptr.last().unwrap());
        Csr {
            nrows,
            ncols,
            row_ptr,
            col_idx: self.col_idx.clone(),
            vals: self.vals.clone(),
        }
    }

    /// Dense representation (tests / small graphs only).
    pub fn to_dense(&self) -> Matrix {
        let mut m = Matrix::zeros(self.nrows, self.ncols);
        for r in 0..self.nrows {
            let (cols, vals) = self.row(r);
            for (&c, &v) in cols.iter().zip(vals) {
                m.set(r, c as usize, v);
            }
        }
        m
    }

    /// Row sums (used in normalisation sanity tests).
    pub fn row_sums(&self) -> Vec<f32> {
        (0..self.nrows)
            .map(|r| self.row(r).1.iter().sum())
            .collect()
    }

    /// Split `0..nrows` into at most `chunks` contiguous row ranges of
    /// (approximately) equal *nonzero* count, via binary search on the
    /// `row_ptr` prefix sums. SpMM cost is proportional to nnz per row,
    /// not row count, so this is the load-balanced partition for the
    /// power-law degree distributions community partitioning concentrates
    /// (equal-row chunking can leave one chunk holding nearly all the
    /// work). The per-row kernel is unchanged, so any chunking — balanced
    /// or uniform — produces bitwise-identical results.
    ///
    /// Ranges are non-empty, consecutive and cover `0..nrows` exactly; an
    /// all-empty matrix falls back to uniform row splitting.
    pub fn balanced_row_chunks(&self, chunks: usize) -> Vec<(usize, usize)> {
        let chunks = chunks.max(1).min(self.nrows.max(1));
        if self.nrows == 0 {
            return Vec::new();
        }
        let nnz = self.nnz();
        if chunks == 1 || nnz == 0 {
            return crate::util::pool::uniform_chunks(chunks, self.nrows);
        }
        let target = nnz.div_ceil(chunks);
        let mut out = Vec::with_capacity(chunks);
        let mut lo = 0usize;
        for ci in 1..=chunks {
            if lo >= self.nrows {
                break;
            }
            let hi = if ci == chunks {
                self.nrows
            } else {
                // First row index whose prefix nnz reaches the chunk's
                // cumulative target; forced past `lo` so every chunk is
                // non-empty even when one row dominates the nnz budget.
                let goal = (ci * target).min(nnz) as u32;
                self.row_ptr
                    .partition_point(|&p| p < goal)
                    .clamp(lo + 1, self.nrows)
            };
            out.push((lo, hi));
            lo = hi;
        }
        if let Some(last) = out.last_mut() {
            last.1 = self.nrows;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn path_graph(n: usize) -> Graph {
        Graph::from_edges(n, &(0..n - 1).map(|i| (i, i + 1)).collect::<Vec<_>>())
    }

    #[test]
    fn graph_dedup_and_canonical() {
        let g = Graph::from_edges(4, &[(0, 1), (1, 0), (2, 3), (3, 3), (2, 3)]);
        assert_eq!(g.num_edges(), 2);
        assert!(g.has_edge(0, 1) && g.has_edge(1, 0));
        assert!(!g.has_edge(3, 3));
        assert_eq!(g.degree(3), 1);
    }

    #[test]
    fn normalized_adjacency_known_values() {
        // Path 0-1-2: deg = [1,2,1]; d+1 = [2,3,2].
        let g = path_graph(3);
        let a = g.normalized_adjacency();
        assert!((a.get(0, 0) - 0.5).abs() < 1e-6); // 1/2
        assert!((a.get(1, 1) - 1.0 / 3.0).abs() < 1e-6);
        assert!((a.get(0, 1) - 1.0 / (2.0f32 * 3.0).sqrt()).abs() < 1e-6);
        assert_eq!(a.get(0, 2), 0.0);
        assert!(a.is_symmetric(1e-7));
    }

    #[test]
    fn slice_rows_matches_full_spmm_rows() {
        let mut rng = Rng::new(11);
        let mut trips = Vec::new();
        for r in 0..20 {
            for c in 0..20 {
                if rng.gen_bool(0.2) {
                    trips.push((r, c, rng.gen_f32()));
                }
            }
        }
        let a = Csr::from_triplets(20, 20, &trips);
        let x = Matrix::glorot(20, 7, &mut rng);
        let full = a.spmm(&x);
        for (lo, hi) in [(0, 20), (3, 9), (9, 9), (19, 20)] {
            let s = a.slice_rows(lo, hi);
            assert_eq!(s.nrows(), hi - lo);
            assert_eq!(s.ncols(), 20);
            let got = s.spmm(&x);
            assert_eq!(got.data(), full.slice_rows(lo, hi).data(), "{lo}..{hi}");
        }
    }

    #[test]
    fn balanced_row_chunks_cover_and_balance() {
        // Power-law-ish rows: row r has ~r nonzeros, so uniform row
        // splitting would put most of the work in the last chunk.
        let mut trips = Vec::new();
        for r in 0..40usize {
            for c in 0..r.min(39) {
                trips.push((r, c, 1.0f32));
            }
        }
        let a = Csr::from_triplets(40, 40, &trips);
        for chunks in [1usize, 2, 3, 7, 8, 40, 100] {
            let b = a.balanced_row_chunks(chunks);
            assert!(!b.is_empty());
            assert!(b.len() <= chunks.max(1).min(40));
            let mut next = 0usize;
            for &(lo, hi) in &b {
                assert_eq!(lo, next, "chunks={chunks}");
                assert!(hi > lo, "chunks={chunks}");
                next = hi;
            }
            assert_eq!(next, 40, "chunks={chunks}");
        }
        // Balance: at 4 chunks no chunk should hold more than ~2x the
        // ideal nnz share (the heaviest single row bounds the overshoot).
        let b = a.balanced_row_chunks(4);
        let ideal = a.nnz() as f64 / 4.0;
        for &(lo, hi) in &b {
            let nnz: usize = (lo..hi).map(|r| a.row(r).0.len()).sum();
            assert!(
                (nnz as f64) < 2.0 * ideal + 40.0,
                "chunk {lo}..{hi} holds {nnz} nnz (ideal {ideal})"
            );
        }
    }

    #[test]
    fn balanced_row_chunks_degenerate_shapes() {
        // Empty matrix → uniform fallback still covers all rows.
        let empty = Csr::from_triplets(5, 5, &[]);
        let b = empty.balanced_row_chunks(3);
        assert_eq!(b.iter().map(|&(l, h)| h - l).sum::<usize>(), 5);
        // One row owning every nonzero: chunks stay non-empty and cover.
        let trips: Vec<(usize, usize, f32)> = (0..6).map(|c| (2usize, c, 1.0f32)).collect();
        let spike = Csr::from_triplets(6, 6, &trips);
        for chunks in [2usize, 3, 6] {
            let b = spike.balanced_row_chunks(chunks);
            let mut next = 0usize;
            for &(lo, hi) in &b {
                assert_eq!(lo, next);
                assert!(hi > lo);
                next = hi;
            }
            assert_eq!(next, 6);
        }
        // Zero-row matrix.
        assert!(Csr::from_triplets(0, 4, &[]).balanced_row_chunks(4).is_empty());
    }

    #[test]
    fn normalized_adjacency_isolated_node() {
        let g = Graph::from_edges(3, &[(0, 1)]);
        let a = g.normalized_adjacency();
        // Node 2 is isolated: Ã[2,2] = 1/(0+1) = 1.
        assert!((a.get(2, 2) - 1.0).abs() < 1e-6);
        assert_eq!(a.row(2).0.len(), 1);
    }

    #[test]
    fn nnz_guard_accepts_up_to_u32_max() {
        assert_eq!(checked_ptr_u32(0), 0);
        assert_eq!(checked_ptr_u32(u32::MAX as usize), u32::MAX);
    }

    #[test]
    #[should_panic(expected = "exceeds the u32 row_ptr ceiling")]
    fn nnz_guard_rejects_beyond_u32() {
        // A real > 2³²-nnz matrix would need ~34 GB, so exercise the
        // guard directly (it is the same code path `from_rows` and
        // `transpose` run per row).
        checked_ptr_u32(u32::MAX as usize + 1);
    }

    #[test]
    fn csr_triplets_merge_duplicates() {
        let c = Csr::from_triplets(2, 2, &[(0, 1, 1.0), (0, 1, 2.0), (1, 0, 5.0)]);
        assert_eq!(c.nnz(), 2);
        assert_eq!(c.get(0, 1), 3.0);
        assert_eq!(c.get(1, 0), 5.0);
    }

    #[test]
    fn spmm_matches_dense_matmul() {
        let mut rng = Rng::new(10);
        for _ in 0..5 {
            let n = 3 + rng.gen_range(20);
            let m = 3 + rng.gen_range(20);
            let k = 1 + rng.gen_range(8);
            let mut trips = Vec::new();
            for r in 0..n {
                for c in 0..m {
                    if rng.gen_bool(0.2) {
                        trips.push((r, c, rng.gen_f32() * 2.0 - 1.0));
                    }
                }
            }
            let s = Csr::from_triplets(n, m, &trips);
            let x = Matrix::glorot(m, k, &mut rng);
            let fast = s.spmm(&x);
            let slow = s.to_dense().matmul(&x);
            assert!(fast.max_abs_diff(&slow) < 1e-5);
        }
    }

    #[test]
    fn transpose_matches_dense() {
        let mut rng = Rng::new(11);
        let mut trips = Vec::new();
        for r in 0..13 {
            for c in 0..7 {
                if rng.gen_bool(0.3) {
                    trips.push((r, c, rng.gen_f32()));
                }
            }
        }
        let s = Csr::from_triplets(13, 7, &trips);
        let t = s.transpose();
        assert_eq!(t.nrows(), 7);
        assert_eq!(t.ncols(), 13);
        assert!(t.to_dense().max_abs_diff(&s.to_dense().transpose()) < 1e-7);
        // Double transpose is identity.
        assert_eq!(t.transpose(), s);
    }

    #[test]
    fn spectral_property_perron_eigenvector() {
        // Ã (D+I)^{1/2} 1 = (D+I)^{1/2} 1 exactly: v_i = sqrt(d_i + 1) is an
        // eigenvector with eigenvalue 1 (the Perron vector of the
        // self-looped normalised adjacency).
        let g = path_graph(10);
        let a = g.normalized_adjacency();
        let v = Matrix::from_fn(10, 1, |r, _| ((g.degree(r) + 1) as f32).sqrt());
        let av = a.spmm(&v);
        assert!(av.max_abs_diff(&v) < 1e-5);
        // And all row sums are strictly positive.
        for s in a.row_sums() {
            assert!(s > 0.0);
        }
    }
}
