//! Community block decomposition of the normalised adjacency.
//!
//! Given a partition `V = ∪ V_m`, the paper splits `Ã` into `M×M` blocks
//! `Ã_{m,r}` (Problem 3). [`split_blocks`] extracts those blocks as CSR
//! matrices over *community-local* indices, together with the neighbor sets
//! `N_m = { r ≠ m | Ã_{m,r} ≠ 0 }` that drive the message protocol.

use super::Csr;
use std::collections::BTreeSet;

/// The `M×M` block view of a square sparse matrix under a node partition.
#[derive(Clone, Debug)]
pub struct BlockMatrix {
    /// Number of communities M.
    pub m: usize,
    /// Community sizes n_m (unpadded).
    pub sizes: Vec<usize>,
    /// Global node ids per community (defines local ordering).
    pub members: Vec<Vec<usize>>,
    /// blocks[m * M + r] = Ã_{m,r} (n_m × n_r, local indices); `None` when
    /// structurally empty.
    blocks: Vec<Option<Csr>>,
    /// Neighbor community sets N_m (paper §2), excluding m itself.
    pub neighbors: Vec<Vec<usize>>,
}

impl BlockMatrix {
    pub fn block(&self, m: usize, r: usize) -> Option<&Csr> {
        self.blocks[m * self.m + r].as_ref()
    }

    /// Communication volume if each non-empty off-diagonal block implies a
    /// message of `bytes_per_row * n_r` bytes — used by partition ablations.
    pub fn offdiag_nnz(&self) -> usize {
        let mut t = 0;
        for m in 0..self.m {
            for r in 0..self.m {
                if m != r {
                    if let Some(b) = self.block(m, r) {
                        t += b.nnz();
                    }
                }
            }
        }
        t
    }

    /// Total nnz across all blocks (should equal the source matrix nnz).
    pub fn total_nnz(&self) -> usize {
        self.blocks
            .iter()
            .flatten()
            .map(|b| b.nnz())
            .sum()
    }
}

/// Split square sparse `a` into blocks under `members` (disjoint cover of
/// `0..a.nrows()`).
pub fn split_blocks(a: &Csr, members: &[Vec<usize>]) -> BlockMatrix {
    let n = a.nrows();
    assert_eq!(a.ncols(), n, "split_blocks needs a square matrix");
    let m = members.len();

    // global -> (community, local index); also validates disjoint cover.
    let mut owner = vec![usize::MAX; n];
    let mut local = vec![u32::MAX; n];
    for (ci, mem) in members.iter().enumerate() {
        for (li, &g) in mem.iter().enumerate() {
            assert!(g < n, "member {g} out of range");
            assert_eq!(owner[g], usize::MAX, "node {g} in two communities");
            owner[g] = ci;
            local[g] = li as u32;
        }
    }
    assert!(
        owner.iter().all(|&o| o != usize::MAX),
        "partition does not cover all nodes"
    );

    // Accumulate triplets per block.
    let mut trips: Vec<Vec<(usize, usize, f32)>> = vec![Vec::new(); m * m];
    for (ci, mem) in members.iter().enumerate() {
        for (li, &g) in mem.iter().enumerate() {
            let (cols, vals) = a.row(g);
            for (&c, &v) in cols.iter().zip(vals) {
                let cj = owner[c as usize];
                let lj = local[c as usize] as usize;
                trips[ci * m + cj].push((li, lj, v));
            }
        }
    }

    let sizes: Vec<usize> = members.iter().map(|v| v.len()).collect();
    let mut blocks = Vec::with_capacity(m * m);
    let mut neighbors: Vec<BTreeSet<usize>> = vec![BTreeSet::new(); m];
    for mi in 0..m {
        for r in 0..m {
            let t = &trips[mi * m + r];
            if t.is_empty() {
                blocks.push(None);
            } else {
                if mi != r {
                    neighbors[mi].insert(r);
                }
                blocks.push(Some(Csr::from_triplets(sizes[mi], sizes[r], t)));
            }
        }
    }

    BlockMatrix {
        m,
        sizes,
        members: members.to_vec(),
        blocks,
        neighbors: neighbors
            .into_iter()
            .map(|s| s.into_iter().collect())
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;
    use crate::tensor::Matrix;
    use crate::util::rng::Rng;

    /// The Figure-1 style fixture: three communities {a,b,c,d}, {e,f},
    /// {g,h,i} with one bridge c-g and d-g (community 1 <-> 3).
    fn fig1() -> (Graph, Vec<Vec<usize>>) {
        // nodes: a=0 b=1 c=2 d=3 | e=4 f=5 | g=6 h=7 i=8
        let edges = [
            (0, 1),
            (0, 2),
            (1, 3),
            (2, 3), // community 0 internal
            (4, 5), // community 1 internal
            (6, 7),
            (7, 8),
            (6, 8), // community 2 internal
            (2, 6),
            (3, 6), // bridges 0 <-> 2
        ];
        let g = Graph::from_edges(9, &edges);
        let members = vec![vec![0, 1, 2, 3], vec![4, 5], vec![6, 7, 8]];
        (g, members)
    }

    #[test]
    fn neighbor_sets_match_paper_fig1() {
        let (g, members) = fig1();
        let a = g.normalized_adjacency();
        let b = split_blocks(&a, &members);
        // N_1 = {3} in paper terms (0-indexed: N_0 = {2}).
        assert_eq!(b.neighbors[0], vec![2]);
        assert_eq!(b.neighbors[1], Vec::<usize>::new());
        assert_eq!(b.neighbors[2], vec![0]);
        // Symmetry of neighborhood relation.
        for m in 0..b.m {
            for &r in &b.neighbors[m] {
                assert!(b.neighbors[r].contains(&m), "N not symmetric: {m} vs {r}");
            }
        }
    }

    #[test]
    fn blocks_reassemble_to_full_matrix() {
        let (g, members) = fig1();
        let a = g.normalized_adjacency();
        let b = split_blocks(&a, &members);
        assert_eq!(b.total_nnz(), a.nnz());
        // Check entries: Ã[g_i, g_j] == block[m,r][l_i, l_j].
        for (m, mem_m) in members.iter().enumerate() {
            for (r, mem_r) in members.iter().enumerate() {
                for (li, &gi) in mem_m.iter().enumerate() {
                    for (lj, &gj) in mem_r.iter().enumerate() {
                        let expect = a.get(gi, gj);
                        let got = b.block(m, r).map(|c| c.get(li, lj)).unwrap_or(0.0);
                        assert!(
                            (expect - got).abs() < 1e-7,
                            "mismatch at global ({gi},{gj}) block ({m},{r})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn blockwise_spmm_equals_full_spmm() {
        // The paper's 'no performance loss' property: block-assembled
        // products equal the monolithic product (DESIGN.md §4 invariant 4).
        let (g, members) = fig1();
        let a = g.normalized_adjacency();
        let b = split_blocks(&a, &members);
        let mut rng = Rng::new(20);
        let x = Matrix::glorot(9, 4, &mut rng);
        let full = a.spmm(&x);
        // Per-community local features.
        let locals: Vec<Matrix> = members.iter().map(|mem| x.gather_rows(mem)).collect();
        for (m, mem) in members.iter().enumerate() {
            let mut acc = Matrix::zeros(mem.len(), 4);
            for r in 0..b.m {
                if let Some(blk) = b.block(m, r) {
                    acc.add_assign(&blk.spmm(&locals[r]));
                }
            }
            let expect = full.gather_rows(mem);
            assert!(
                acc.max_abs_diff(&expect) < 1e-5,
                "community {m} blockwise product differs"
            );
        }
    }

    #[test]
    #[should_panic(expected = "in two communities")]
    fn overlapping_partition_rejected() {
        let (g, _) = fig1();
        let a = g.normalized_adjacency();
        let _ = split_blocks(&a, &[vec![0, 1], vec![1, 2, 3, 4, 5, 6, 7, 8]]);
    }

    #[test]
    #[should_panic(expected = "does not cover")]
    fn incomplete_partition_rejected() {
        let (g, _) = fig1();
        let a = g.normalized_adjacency();
        let _ = split_blocks(&a, &[vec![0, 1, 2]]);
    }
}
