//! # CGCN — Community-based Layerwise Distributed Training of GCNs
//!
//! A three-layer (Rust + JAX + Pallas, AOT via PJRT) reproduction of
//! *"Community-based Layerwise Distributed Training of Graph Convolutional
//! Networks"* (Li et al., 2021).
//!
//! The crate is organised bottom-up:
//!
//! - [`util`] — in-house substrates (RNG, JSON, CLI, logging, wire format,
//!   stats, property-testing) — the offline registry only carries the `xla`
//!   crate closure, so these are built from scratch.
//! - [`tensor`] — host-side dense f32 matrices.
//! - [`graph`] — CSR graphs, symmetric GCN normalisation, block extraction
//!   and the SpMM hot path.
//! - [`data`] — synthetic Amazon-like SBM datasets (Table 2 statistics) and
//!   a binary dataset format.
//! - [`partition`] — METIS-style multilevel partitioner plus baselines.
//! - [`runtime`] — PJRT bridge: loads AOT-compiled HLO-text artifacts and
//!   executes them from the training hot path (Python never runs here).
//! - [`coordinator`] — the paper's contribution: the community-based
//!   layerwise ADMM trainer (Algorithm 1) with the first/second-order
//!   message protocol (eq. 4), serial and parallel schedules, and
//!   virtual-time accounting.
//! - [`baselines`] — full-batch backprop GCN with GD/Adam/Adagrad/Adadelta.
//! - [`metrics`] — timers, counters and CSV emission for the paper's
//!   tables/figures.
//! - [`config`] — experiment configuration mirroring the paper's settings.
//! - [`bench`] — the micro/macro benchmark harness (criterion is not
//!   available offline).

pub mod bench;
pub mod cmd;
pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod graph;
pub mod metrics;
pub mod partition;
pub mod runtime;
pub mod tensor;
pub mod util;
