//! # CGCN — Community-based Layerwise Distributed Training of GCNs
//!
//! A reproduction of *"Community-based Layerwise Distributed Training of
//! Graph Convolutional Networks"* (Li et al., 2021) with two execution
//! backends: a pure-Rust, pool-parallel [`runtime::NativeBackend`] (always
//! available) and a PJRT/XLA artifact engine (`--features xla`, AOT via
//! the Python/Pallas layer under `python/`).
//!
//! The crate is organised bottom-up:
//!
//! - [`util`] — in-house substrates (RNG, JSON, CLI, logging, wire format,
//!   stats, property-testing, the worker pool) — the offline registry has
//!   no ecosystem crates, so these are built from scratch.
//! - [`tensor`] — host-side dense f32 matrices.
//! - [`graph`] — CSR graphs, symmetric GCN normalisation, block extraction,
//!   induced-subgraph renormalisation (mini-batching) and the SpMM hot path.
//! - [`data`] — synthetic Amazon-like SBM datasets (Table 2 statistics) and
//!   a binary dataset format.
//! - [`partition`] — METIS-style multilevel partitioner plus baselines.
//! - [`community`] — community detection (Louvain, LPA) with a
//!   deterministic merge-to-M mapping onto balanced agents, partition
//!   quality analytics (modularity/edge-cut/conductance), and the
//!   `cgcn-partition-v1` assignment file format (DESIGN.md §13).
//! - [`runtime`] — the [`runtime::ComputeBackend`] trait with the native
//!   and (feature-gated) XLA implementations; every dense training kernel
//!   dispatches through it.
//! - [`coordinator`] — the paper's contribution: the community-based
//!   layerwise ADMM trainer (Algorithm 1) with the first/second-order
//!   message protocol (eq. 4) factored into per-community agents
//!   ([`coordinator::CommunityAgent`]); executors run the agents serially
//!   with virtual-time accounting or as real pool tasks exchanging
//!   messages over channels (`--exec serial|threads`), plus the elastic
//!   distributed runtime: a fault-tolerant leader over a transport trait
//!   (TCP worker processes with heartbeats, in-process channel threads,
//!   and a deterministic fault-injecting simulator), `.cgck` training
//!   checkpoints and bitwise-identical crash recovery (DESIGN.md §8).
//! - [`baselines`] — backprop GCN training: full-batch GD/Adam/Adagrad/
//!   Adadelta plus the stochastic community mini-batch engine
//!   ([`baselines::ClusterGcnTrainer`], `train --method cluster-gcn`).
//! - [`serve`] — the serving half: the `.cgnm` model-snapshot codec, the
//!   community-sharded [`serve::InferenceSession`] activation cache, the
//!   micro-batching multi-threaded TCP inference server, and the load
//!   generator (`train --save` → `serve` → `query`/`loadgen`).
//! - [`metrics`] — timers, counters and CSV emission for the paper's
//!   tables/figures.
//! - [`obs`] — zero-dependency telemetry: sharded counter/gauge/histogram
//!   registry, tracing spans with Chrome trace-event export
//!   (`--trace-out`), Prometheus-style exposition (`stats` subcommand,
//!   `--metrics-out`), gated by `CGCN_OBS` (DESIGN.md §10).
//! - [`config`] — experiment configuration mirroring the paper's settings.
//! - [`bench`] — the micro/macro benchmark harness (criterion is not
//!   available offline).
//!
//! See `DESIGN.md` for how the backend trait, the worker pool and the
//! virtual-time clock compose.

pub mod bench;
pub mod cmd;
pub mod baselines;
pub mod community;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod graph;
pub mod metrics;
pub mod obs;
pub mod partition;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod util;
