//! In-tree facade shim for the `anyhow` crate (offline build — no registry).
//!
//! Implements the subset cgcn uses: a context-chaining [`Error`], the
//! [`Result`] alias, the [`Context`] extension trait, and the `anyhow!` /
//! `bail!` / `ensure!` macros. Display semantics mirror the real crate:
//! `{}` prints the outermost message, `{:#}` prints the whole chain
//! separated by `: `, and `{:?}` prints the message plus a "Caused by:"
//! block.

use std::fmt;

/// A context-chaining error value. Like `anyhow::Error`, it deliberately
/// does NOT implement `std::error::Error` itself, which is what allows the
/// blanket `From<E: std::error::Error>` conversion powering `?`.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
            source: None,
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error {
            msg: context.to_string(),
            source: Some(Box::new(self)),
        }
    }

    /// Iterate the chain outermost-first (each item's own message).
    pub fn chain(&self) -> Chain<'_> {
        Chain { next: Some(self) }
    }

    /// The root (innermost) message of the chain.
    pub fn root_cause(&self) -> &str {
        let mut cur = self;
        while let Some(next) = cur.source.as_deref() {
            cur = next;
        }
        &cur.msg
    }
}

/// Iterator over an error chain, outermost first.
pub struct Chain<'a> {
    next: Option<&'a Error>,
}

impl<'a> Iterator for Chain<'a> {
    type Item = &'a Error;
    fn next(&mut self) -> Option<&'a Error> {
        let cur = self.next?;
        self.next = cur.source.as_deref();
        Some(cur)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if self.source.is_some() {
            write!(f, "\n\nCaused by:")?;
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, "\n    {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        // Flatten the std source chain into our own.
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Error> = None;
        for msg in msgs.into_iter().rev() {
            err = Some(Error {
                msg,
                source: err.map(Box::new),
            });
        }
        err.expect("at least one message")
    }
}

/// `anyhow::Result<T>` — a `Result` defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any `Display` value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like `anyhow!`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(format!(
                "Condition failed: `{}`",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = Error::from(io_err()).context("reading manifest");
        assert_eq!(format!("{e}"), "reading manifest");
        let full = format!("{e:#}");
        assert!(full.starts_with("reading manifest: "));
        assert!(full.contains("missing thing"));
    }

    #[test]
    fn debug_shows_cause_chain() {
        let e = Error::msg("root").context("mid").context("top");
        let dbg = format!("{e:?}");
        assert!(dbg.starts_with("top"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("root"));
        assert_eq!(e.root_cause(), "root");
        assert_eq!(e.chain().count(), 3);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<u32> {
            let n: u32 = "17".parse()?;
            Ok(n)
        }
        assert_eq!(inner().unwrap(), 17);
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            ensure!(x != 3);
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert!(format!("{}", f(12).unwrap_err()).contains("x too big: 12"));
        assert!(format!("{}", f(3).unwrap_err()).contains("Condition failed"));
        assert!(format!("{}", f(5).unwrap_err()).contains("five"));
        let e = anyhow!("code {}", 7);
        assert_eq!(format!("{e}"), "code 7");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "x")).unwrap_err();
        assert_eq!(format!("{e}"), "missing x");
    }
}
