//! In-tree facade shim for the `log` crate (offline build — no registry).
//!
//! API-compatible subset: `Level`, `LevelFilter`, `Metadata`, `Record`, the
//! `Log` trait, `set_logger` / `set_max_level`, and the five level macros.
//! Semantics match the real facade for everything this repo does: a single
//! `&'static dyn Log` backend, an atomic max-level gate checked before the
//! record is built, and `module_path!()` as the record target.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Log levels, most to least severe. Discriminants start at 1 so they embed
/// into [`LevelFilter`]'s scale (where `Off = 0`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
#[repr(usize)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

impl Level {
    pub fn as_str(&self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.pad(self.as_str())
    }
}

/// Level filter: `Off` plus every [`Level`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
#[repr(usize)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Metadata about a log record.
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }
    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record, borrowed for the duration of the `Log::log` call.
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }
    pub fn level(&self) -> Level {
        self.metadata.level
    }
    pub fn target(&self) -> &'a str {
        self.metadata.target
    }
    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// The logging backend trait.
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

/// Error returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("attempted to set a logger after one was already installed")
    }
}

impl std::error::Error for SetLoggerError {}

/// Install the global logger (once).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global maximum level checked before records are built.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

/// Current global maximum level.
pub fn max_level() -> usize {
    MAX_LEVEL.load(Ordering::Relaxed)
}

/// Macro plumbing — not part of the public facade.
#[doc(hidden)]
pub fn __private_log(level: Level, target: &str, args: fmt::Arguments) {
    if (level as usize) > max_level() {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let record = Record {
            metadata: Metadata { level, target },
            args,
        };
        if logger.enabled(&record.metadata) {
            logger.log(&record);
        }
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__private_log($lvl, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_vs_filter_ordering() {
        assert!(Level::Error <= LevelFilter::Info);
        assert!(Level::Info <= LevelFilter::Info);
        assert!(Level::Debug > LevelFilter::Info);
        assert!(Level::Trace > LevelFilter::Off);
    }

    #[test]
    fn display_pads() {
        assert_eq!(format!("{:5}", Level::Warn), "WARN ");
        assert_eq!(format!("{}", Level::Error), "ERROR");
    }

    #[test]
    fn macros_are_safe_without_logger() {
        // No logger installed in this test binary — must be a no-op.
        info!("hello {}", 42);
        debug!("dbg");
        trace!("trc");
        warn!("warn");
        error!("err");
    }
}
