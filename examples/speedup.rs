//! Table-3 analogue on one dataset: total / training / communication time
//! of Serial vs Parallel ADMM with the virtual-time accounting (critical
//! path over agents + link-model communication; see DESIGN.md §2 for the
//! 1-core-testbed substitution).
//!
//! ```sh
//! make artifacts && cargo run --release --example speedup -- \
//!     [dataset] [scale] [epochs]        # default: synth-photo 0.25 50
//! ```

use cgcn::config::HyperParams;
use cgcn::coordinator::{AdmmOptions, AdmmTrainer, ExecMode, Workspace};
use cgcn::data::synth;
use cgcn::partition::Method;
use cgcn::runtime::{default_backend, ComputeBackend};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    cgcn::util::logger::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let dataset = argv.first().map(|s| s.as_str()).unwrap_or("synth-photo");
    let scale: f64 = argv.get(1).map(|s| s.parse()).transpose()?.unwrap_or(0.25);
    let epochs: usize = argv.get(2).map(|s| s.parse()).transpose()?.unwrap_or(50);

    let spec = synth::spec_by_name(dataset)
        .ok_or_else(|| anyhow::anyhow!("dataset must be synth-computers or synth-photo"))?;
    let ds = synth::generate(&spec, scale, 17);
    let backend = default_backend();
    log::info!("backend: {}", backend.name());
    let hp = HyperParams::for_dataset(dataset);

    let run = |m: usize, exec: ExecMode| -> anyhow::Result<cgcn::metrics::RunReport> {
        let mut hp_m = hp.clone();
        hp_m.communities = m;
        let ws = Arc::new(Workspace::build(&ds, &hp_m, Method::Metis)?);
        let mut opts = AdmmOptions::for_mode(m);
        opts.exec = exec;
        let mut t = AdmmTrainer::new(ws, backend.clone(), opts)?;
        t.train(epochs, if m == 1 { "serial" } else { "parallel" })
    };

    log::info!("running Serial ADMM (M=1, layers sequential)");
    let serial = run(1, ExecMode::Serial)?;
    log::info!("running Parallel ADMM (M=3 + layer parallelism)");
    let parallel = run(3, ExecMode::Serial)?;
    log::info!("running Parallel ADMM (M=3, real threads)");
    let threaded = run(3, ExecMode::Threads)?;

    println!("\n{} — {} epochs (virtual time, see DESIGN.md §2)", ds.name, epochs);
    println!(
        "{:<22} {:>9} {:>10} {:>14} {:>9}",
        "", "Total(s)", "Train(s)", "Comm(s)", "Speedup"
    );
    println!("{}", serial.table3_row("Serial ADMM", None));
    println!(
        "{}",
        parallel.table3_row(
            "Parallel ADMM (M=3)",
            Some(serial.total_virtual() / parallel.total_virtual())
        )
    );
    println!(
        "

training-time reduction: {:.1}%   comm bytes/epoch: {:.1} MB   wall (1 core): {:.1}s vs {:.1}s",
        100.0 * (1.0 - parallel.total_train() / serial.total_train()),
        parallel.total_bytes() as f64 / parallel.epochs.len() as f64 / 1e6,
        serial.total_wall(),
        parallel.total_wall(),
    );
    println!(
        "real threads (--exec threads): wall {:.1}s vs {:.1}s serial-exec ({:.2}x wall speedup, \
         identical loss: {})",
        threaded.total_wall(),
        parallel.total_wall(),
        parallel.total_wall() / threaded.total_wall(),
        (threaded.epochs.last().unwrap().loss - parallel.epochs.last().unwrap().loss).abs()
            < 1e-12
    );
    Ok(())
}
