//! Quickstart: train a 2-layer GCN on the paper's Figure-1 toy graph with
//! community-based parallel ADMM, and print the per-epoch trajectory.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! Runs on the native backend out of the box; picks up the XLA artifact
//! engine instead when built with `--features xla` after `make artifacts`.

use cgcn::config::HyperParams;
use cgcn::coordinator::{AdmmOptions, AdmmTrainer, Workspace};
use cgcn::data::fixtures;
use cgcn::partition::Method;
use cgcn::runtime::{default_backend, ComputeBackend};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    cgcn::util::logger::init();

    // 1. A dataset: the paper's Figure-1 graph (9 nodes, 3 communities).
    let ds = fixtures::fig1();
    println!("dataset: {} ({} nodes, {} edges)", ds.name, ds.n(), ds.graph.num_edges());

    // 2. Hyper-parameters (paper defaults; tiny dims for the fixture).
    let mut hp = HyperParams::for_dataset(&ds.name);
    hp.hidden = 8;
    hp.communities = 3;

    // 3. Partition into communities + build the padded block workspace.
    let ws = Arc::new(Workspace::build(&ds, &hp, Method::Metis)?);
    println!(
        "partition: sizes={:?} edgecut={} neighbor sets={:?}",
        ws.partition.sizes(),
        ws.edgecut,
        ws.communities.iter().map(|c| c.neighbors.clone()).collect::<Vec<_>>()
    );

    // 4. Pick a compute backend (XLA artifacts when available, else the
    // pure-Rust native backend).
    let backend = default_backend();
    println!("backend: {}", backend.name());

    // 5. Train with community-parallel ADMM.
    let opts = AdmmOptions::for_mode(hp.communities);
    let mut trainer = AdmmTrainer::new(ws, backend, opts)?;
    println!("\n{:>5} {:>10} {:>10} {:>10}", "epoch", "loss", "train", "test");
    for epoch in 0..30 {
        trainer.epoch()?;
        let (train, test, loss) = trainer.evaluate()?;
        if epoch % 3 == 0 || epoch == 29 {
            println!("{epoch:>5} {loss:>10.4} {train:>10.3} {test:>10.3}");
        }
    }
    let (train, test, _) = trainer.evaluate()?;
    println!("\nfinal: train acc {train:.3}, test acc {test:.3}");
    Ok(())
}
