//! End-to-end driver (EXPERIMENTS.md §E2E): train the paper's 2-layer GCN
//! on a synthetic Amazon-statistics dataset with all six methods — Serial
//! ADMM, Parallel ADMM (M=3), Adam, Adagrad, GD, Adadelta — logging the
//! full loss/accuracy curves to `results/e2e_<dataset>.csv` and printing a
//! Figure-2-style summary.
//!
//! ```sh
//! make artifacts && cargo run --release --example train_amazon -- \
//!     [dataset] [scale] [epochs]        # default: synth-photo 0.25 50
//! ```

use cgcn::baselines::{BaselineTrainer, Optimizer};
use cgcn::config::HyperParams;
use cgcn::coordinator::{AdmmOptions, AdmmTrainer, Workspace};
use cgcn::data::synth;
use cgcn::metrics::RunReport;
use cgcn::partition::Method;
use cgcn::runtime::{default_backend, ComputeBackend};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    cgcn::util::logger::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let dataset = argv.first().map(|s| s.as_str()).unwrap_or("synth-photo");
    let scale: f64 = argv.get(1).map(|s| s.parse()).transpose()?.unwrap_or(0.25);
    let epochs: usize = argv.get(2).map(|s| s.parse()).transpose()?.unwrap_or(50);

    let spec = synth::spec_by_name(dataset)
        .ok_or_else(|| anyhow::anyhow!("dataset must be synth-computers or synth-photo"))?;
    let ds = synth::generate(&spec, scale, 17);
    println!(
        "{:<18} {:>7} {:>8} {:>7} {:>7} {:>9} {:>9} {:>8}",
        "dataset", "nodes", "train", "test", "classes", "features", "edges", "avgdeg"
    );
    println!("{}\n", ds.stats_row());

    let backend = default_backend();
    log::info!("backend: {}", backend.name());
    let hp = HyperParams::for_dataset(dataset);
    let mut reports: Vec<RunReport> = Vec::new();

    // --- ADMM serial + parallel -----------------------------------------
    for m in [1usize, 3] {
        let label = if m == 1 { "admm-serial" } else { "admm-parallel" };
        let mut hp_m = hp.clone();
        hp_m.communities = m;
        let ws = Arc::new(Workspace::build(&ds, &hp_m, Method::Metis)?);
        let mut trainer =
            AdmmTrainer::new(ws, backend.clone(), AdmmOptions::for_mode(m))?;
        log::info!("training {label} ({epochs} epochs)");
        let mut rep = trainer.train(epochs, label)?;
        rep.dataset = ds.name.clone();
        reports.push(rep);
    }

    // --- the four baseline optimizers ------------------------------------
    let mut hp_b = hp.clone();
    hp_b.communities = 1;
    let ws = Arc::new(Workspace::build(&ds, &hp_b, Method::Metis)?);
    for name in ["adam", "adagrad", "gd", "adadelta"] {
        let opt = Optimizer::parse(name, None)?;
        let mut trainer = BaselineTrainer::new(ws.clone(), backend.clone(), opt)?;
        log::info!("training {name} ({epochs} epochs)");
        let mut rep = trainer.train(epochs)?;
        rep.dataset = ds.name.clone();
        reports.push(rep);
    }

    // --- CSV + summary -----------------------------------------------------
    std::fs::create_dir_all("results")?;
    let path = format!("results/e2e_{}.csv", ds.name.replace('@', "_"));
    let mut csv = String::new();
    for (i, rep) in reports.iter().enumerate() {
        let body = rep.to_csv();
        csv.push_str(if i == 0 { &body } else { body.split_once('\n').unwrap().1 });
    }
    std::fs::write(&path, &csv)?;
    println!("wrote per-epoch curves to {path}\n");

    println!(
        "{:<16} {:>12} {:>12} {:>12} {:>12}",
        "method", "train acc", "test acc", "best test", "virt time"
    );
    for rep in &reports {
        println!(
            "{:<16} {:>12.3} {:>12.3} {:>12.3} {:>11.2}s",
            rep.method,
            rep.final_train_acc(),
            rep.final_test_acc(),
            rep.best_test_acc(),
            rep.total_virtual()
        );
    }
    Ok(())
}
