//! Memory probe: repeatedly execute one artifact and report RSS growth.
//! (Found and now guards against the `execute`-path literal leak — see
//! runtime/engine.rs BufRef docs. Expect a flat RSS after warmup.)
//!
//! XLA-only: requires `--features xla` + `make artifacts`; the native
//! backend allocates nothing persistent per call.

#[cfg(feature = "xla")]
fn main() -> anyhow::Result<()> {
    use cgcn::runtime::{Engine, In};
    use cgcn::tensor::Matrix;
    use cgcn::util::rng::Rng;

    fn rss_kb() -> usize {
        let s = std::fs::read_to_string("/proc/self/statm").unwrap();
        s.split_whitespace().nth(1).unwrap().parse::<usize>().unwrap() * 4
    }

    let engine = Engine::load(&Engine::default_dir())?;
    let mut rng = Rng::new(1);
    let x = Matrix::glorot(768, 745, &mut rng);
    let w = Matrix::glorot(745, 256, &mut rng);
    let sig = "mm_nn__n768_a745_b256";
    engine.exec(sig, &[In::Mat(&x), In::Mat(&w)])?;
    let r0 = rss_kb();
    for i in 0..200 {
        engine.exec(sig, &[In::Mat(&x), In::Mat(&w)])?;
        if i % 50 == 49 {
            println!(
                "iter {i}: rss {} KB (delta {} KB)",
                rss_kb(),
                rss_kb().saturating_sub(r0)
            );
        }
    }
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn main() {
    eprintln!("leak_probe probes the PJRT engine — rebuild with --features xla");
}
