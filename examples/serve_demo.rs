//! Serve demo: the full train → snapshot → serve → query loop in one
//! process, on the paper's Figure-1 toy graph.
//!
//! ```sh
//! cargo run --release --example serve_demo
//! ```

use cgcn::config::HyperParams;
use cgcn::coordinator::{AdmmOptions, AdmmTrainer, Workspace};
use cgcn::partition::Method;
use cgcn::runtime::default_backend;
use cgcn::serve::{load_model, serve, InferenceSession, ServeClient, ServeOptions, SnapshotMeta};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    cgcn::util::logger::init();

    // 1. Train a small model (see examples/quickstart.rs for the
    // training walkthrough).
    let ds = cgcn::cmd::load_dataset("fig1", 1.0, 17)?;
    let mut hp = HyperParams::for_dataset("fig1");
    hp.hidden = 8;
    hp.communities = 3;
    hp.seed = 17;
    let ws = Arc::new(Workspace::build(&ds, &hp, Method::Metis)?);
    let backend = default_backend();
    let mut trainer = AdmmTrainer::new(ws.clone(), backend.clone(), AdmmOptions::for_mode(3))?;
    trainer.train(30, "demo")?;
    let (train_acc, test_acc, _) = trainer.evaluate()?;
    println!("trained: train acc {train_acc:.3}, test acc {test_acc:.3}");

    // 2. Snapshot to .cgnm and load it back — the file is all a server
    // needs (the workspace rebuilds deterministically from metadata).
    let path = std::env::temp_dir().join("cgcn_serve_demo.cgnm");
    trainer.save_model(
        &path,
        SnapshotMeta {
            label: "demo".into(),
            dataset: "fig1".into(),
            scale: 1.0,
            seed: 17,
            partition: "metis".into(),
            communities: 3,
            hidden: 8,
            layers: ws.layers,
        },
    )?;
    let snap = load_model(&path)?;
    println!("snapshot: {} bytes at {}", snap.to_bytes().len(), path.display());

    // 3. Serve it and query over TCP.
    let mut session = InferenceSession::from_snapshot(&snap, backend)?;
    session.warm_all()?;
    let handle = serve(
        session,
        &ServeOptions {
            addr: "127.0.0.1:0".into(),
            threads: 2,
            batch_window_us: 200,
            max_batch: 64,
        },
    )?;
    let addr = handle.addr().to_string();
    println!("serving on {addr}");

    let mut client = ServeClient::connect(&addr)?;
    let info = client.info()?;
    let nodes: Vec<usize> = (0..info.n).collect();
    let rows = client.query(&nodes)?;
    println!("\n{:>5} {:>6} {:>6}", "node", "label", "pred");
    for (row, &id) in rows.iter().zip(&nodes) {
        let pred = cgcn::tensor::argmax(row);
        println!("{id:>5} {:>6} {pred:>6}", ds.labels[id]);
    }
    let stats = client.stats()?;
    println!(
        "\nserver counters: {} requests, {} nodes, {} batches",
        stats.requests, stats.nodes, stats.batches
    );
    drop(client);
    handle.stop();
    std::fs::remove_file(&path).ok();
    Ok(())
}
