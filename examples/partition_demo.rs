//! Figure-1 analogue: show how the partitioner decomposes graphs into
//! communities with neighbor sets, and compare METIS-style multilevel
//! partitioning against the random / BFS baselines on a synthetic
//! co-purchase graph.
//!
//! ```sh
//! cargo run --release --example partition_demo
//! ```

use cgcn::data::{fixtures, synth};
use cgcn::graph::split_blocks;
use cgcn::partition::{partition, Method};

fn main() {
    cgcn::util::logger::init();

    // --- the paper's Figure-1 graph -------------------------------------
    let ds = fixtures::fig1();
    let a = ds.graph.normalized_adjacency();
    let p = partition(&ds.graph, 3, Method::Metis, 7);
    let blocks = split_blocks(&a, &p.members);
    println!("Figure-1 graph: {} nodes, {} edges", ds.n(), ds.graph.num_edges());
    for (m, mem) in p.members.iter().enumerate() {
        println!(
            "  community {m}: nodes {mem:?}  N_{m} = {:?}",
            blocks.neighbors[m]
        );
    }
    println!("  edgecut = {} edges\n", p.edgecut(&ds.graph));

    // --- partitioner comparison on a synthetic co-purchase graph ---------
    let ds = synth::generate(&synth::AMAZON_PHOTO, 0.25, 7);
    println!(
        "{} : {} nodes, {} edges, avg degree {:.1}",
        ds.name,
        ds.n(),
        ds.graph.num_edges(),
        ds.graph.avg_degree()
    );
    println!(
        "\n{:<10} {:>9} {:>10} {:>11} {:>14}",
        "method", "edgecut", "cut frac", "imbalance", "offdiag nnz"
    );
    for method in [Method::Metis, Method::Bfs, Method::Random] {
        let p = partition(&ds.graph, 3, method, 7);
        let a = ds.graph.normalized_adjacency();
        let blocks = split_blocks(&a, &p.members);
        let cut = p.edgecut(&ds.graph);
        println!(
            "{:<10} {:>9} {:>9.1}% {:>11.3} {:>14}",
            method.name(),
            cut,
            100.0 * cut as f64 / ds.graph.num_edges() as f64,
            p.imbalance(ds.n()),
            blocks.offdiag_nnz()
        );
    }
    println!(
        "\n(lower edgecut ⇒ smaller p/s messages ⇒ less communication in\n\
         the parallel ADMM epoch — quantified in benches/ablation_partition)"
    );
}
