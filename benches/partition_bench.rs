//! Partition benchmark: community detection vs the edge-cut baselines.
//!
//! Sweeps every partitioner (louvain, lpa, metis, random, bfs) over the
//! synthetic Table-2 twins, recording detection time, the full quality
//! report (modularity, edge-cut, boundary volume, conductance, balance),
//! and the downstream cost that quality is supposed to buy: time per
//! ADMM epoch training on the resulting partition. Results land in
//! `BENCH_partition.json`.
//!
//! Env knobs:
//!   CGCN_BENCH_QUICK=1    — CI quick mode: smaller graphs, fewer epochs,
//!                           downstream ADMM timed on synth-photo only.
//!   CGCN_BENCH_PARTITION_GATE=1 — exit non-zero unless, on every synth
//!                           graph, louvain modularity beats random by at
//!                           least 0.15 and louvain edge-cut stays within
//!                           2x of metis.
//!   CGCN_BENCH_EPOCHS     — timed epochs per downstream cell.
//!   CGCN_BENCH_PARTITION_SCALE — synth node-count scale override.

use cgcn::community;
use cgcn::config::HyperParams;
use cgcn::coordinator::{AdmmOptions, AdmmTrainer, Workspace};
use cgcn::data::synth;
use cgcn::partition::{partition_with_runtime, Method};
use cgcn::runtime::{ComputeBackend, NativeBackend};
use cgcn::util::json::Json;
use cgcn::util::pool::Runtime;
use std::sync::Arc;
use std::time::Instant;

fn env_or<T: std::str::FromStr>(key: &str, default: T) -> T {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn env_flag(key: &str) -> bool {
    std::env::var(key).map(|v| v == "1" || v == "true").unwrap_or(false)
}

/// One (graph, method) cell: quality + detection time + downstream cost.
struct Cell {
    graph: String,
    method: &'static str,
    m: usize,
    detect_s: f64,
    modularity: f64,
    edge_cut: usize,
    cut_fraction: f64,
    boundary_nodes: usize,
    imbalance: f64,
    max_conductance: f64,
    /// Seconds per downstream ADMM epoch on this partition (0 = not timed).
    admm_epoch_s: f64,
}

impl Cell {
    fn json(&self) -> Json {
        Json::obj(vec![
            ("graph", Json::str(&self.graph)),
            ("method", Json::str(self.method)),
            ("m", Json::num(self.m as f64)),
            ("detect_s", Json::num(self.detect_s)),
            ("modularity", Json::num(self.modularity)),
            ("edge_cut", Json::num(self.edge_cut as f64)),
            ("cut_fraction", Json::num(self.cut_fraction)),
            ("boundary_nodes", Json::num(self.boundary_nodes as f64)),
            ("imbalance", Json::num(self.imbalance)),
            ("max_conductance", Json::num(self.max_conductance)),
            ("admm_epoch_s", Json::num(self.admm_epoch_s)),
        ])
    }
}

/// Gate margins: louvain must beat random's modularity by this much and
/// keep its edge-cut within this factor of metis.
const MOD_MARGIN: f64 = 0.15;
const CUT_FACTOR: f64 = 2.0;

fn main() -> anyhow::Result<()> {
    cgcn::util::logger::init();
    let quick = env_flag("CGCN_BENCH_QUICK");
    let gate = env_flag("CGCN_BENCH_PARTITION_GATE");
    let scale: f64 = env_or("CGCN_BENCH_PARTITION_SCALE", if quick { 0.1 } else { 0.25 });
    let epochs: usize = env_or("CGCN_BENCH_EPOCHS", if quick { 2 } else { 5 });
    let m = 3usize; // the paper's community count
    let seed = 17u64;
    let rt = Runtime::new(8);
    println!(
        "partition_bench: scale {scale}, m {m}, {epochs} timed epochs{}",
        if quick { " (quick mode)" } else { "" }
    );

    let graphs: [(&str, &synth::SynthSpec); 2] = [
        ("synth-photo", &synth::AMAZON_PHOTO),
        ("synth-computers", &synth::AMAZON_COMPUTERS),
    ];
    let mut cells: Vec<Cell> = Vec::new();
    let mut gate_rows: Vec<Json> = Vec::new();
    let mut gate_ok = true;
    for (gname, spec) in graphs {
        let ds = Arc::new(synth::generate(spec, scale, seed));
        println!(
            "\n{gname}: {} nodes, {} edges",
            ds.n(),
            ds.graph.num_edges()
        );
        // Downstream ADMM on every graph is slow; quick mode times only
        // the first graph and reports 0 for the rest (logged, not silent).
        let downstream = !quick || gname == "synth-photo";
        if !downstream {
            println!("(quick mode: skipping downstream ADMM epochs on {gname})");
        }
        let mut mod_by: std::collections::HashMap<&str, f64> = std::collections::HashMap::new();
        let mut cut_by: std::collections::HashMap<&str, usize> = std::collections::HashMap::new();
        for method in Method::ALL {
            let t0 = Instant::now();
            let p = partition_with_runtime(&ds.graph, m, method, seed, Some(&rt));
            let detect_s = t0.elapsed().as_secs_f64();
            let q = community::evaluate(&ds.graph, &p, method.name());
            let admm_epoch_s = if downstream {
                let mut hp = HyperParams::for_dataset(gname);
                hp.communities = m;
                hp.seed = seed;
                let ws = Arc::new(Workspace::from_partition(&ds, &hp, p.clone())?);
                let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::with_threads(8));
                let mut trainer = AdmmTrainer::new(ws, backend, AdmmOptions::for_mode(m))?;
                trainer.train(1, "warmup")?;
                let t0 = Instant::now();
                trainer.train(epochs, "bench")?;
                t0.elapsed().as_secs_f64() / epochs as f64
            } else {
                0.0
            };
            println!(
                "{:<8} detect {:>8.3}s  Q {:>7.4}  cut {:>7} ({:>5.1}%)  boundary {:>6}  \
                 imbal {:>5.3}  admm {:>8.4}s/epoch",
                method.name(),
                detect_s,
                q.modularity,
                q.edge_cut,
                q.cut_fraction * 100.0,
                q.boundary_nodes,
                q.imbalance,
                admm_epoch_s
            );
            mod_by.insert(method.name(), q.modularity);
            cut_by.insert(method.name(), q.edge_cut);
            cells.push(Cell {
                graph: gname.to_string(),
                method: method.name(),
                m,
                detect_s,
                modularity: q.modularity,
                edge_cut: q.edge_cut,
                cut_fraction: q.cut_fraction,
                boundary_nodes: q.boundary_nodes,
                imbalance: q.imbalance,
                max_conductance: q.max_conductance,
                admm_epoch_s,
            });
        }
        let (lv_mod, rnd_mod) = (mod_by["louvain"], mod_by["random"]);
        let (lv_cut, metis_cut) = (cut_by["louvain"], cut_by["metis"]);
        let mod_ok = lv_mod >= rnd_mod + MOD_MARGIN;
        let cut_ok = (lv_cut as f64) <= CUT_FACTOR * metis_cut.max(1) as f64;
        println!(
            "{gname} gate: louvain Q {lv_mod:.4} vs random {rnd_mod:.4} (margin {MOD_MARGIN}) \
             [{}]; louvain cut {lv_cut} vs metis {metis_cut} (factor {CUT_FACTOR}) [{}]",
            if mod_ok { "ok" } else { "FAIL" },
            if cut_ok { "ok" } else { "FAIL" }
        );
        gate_ok &= mod_ok && cut_ok;
        gate_rows.push(Json::obj(vec![
            ("graph", Json::str(gname)),
            ("louvain_modularity", Json::num(lv_mod)),
            ("random_modularity", Json::num(rnd_mod)),
            ("modularity_margin", Json::num(MOD_MARGIN)),
            ("modularity_ok", Json::num(if mod_ok { 1.0 } else { 0.0 })),
            ("louvain_edge_cut", Json::num(lv_cut as f64)),
            ("metis_edge_cut", Json::num(metis_cut as f64)),
            ("cut_factor", Json::num(CUT_FACTOR)),
            ("cut_ok", Json::num(if cut_ok { 1.0 } else { 0.0 })),
        ]));
    }

    let out = Json::obj(vec![
        ("bench", Json::str("partition_bench")),
        ("scale", Json::num(scale)),
        ("m", Json::num(m as f64)),
        ("quick", Json::num(if quick { 1.0 } else { 0.0 })),
        ("cells", Json::arr(cells.iter().map(Cell::json).collect())),
        ("gate", Json::arr(gate_rows)),
    ]);
    std::fs::write("BENCH_partition.json", out.to_pretty() + "\n")?;
    println!("\n(wrote BENCH_partition.json)");
    if gate && !gate_ok {
        anyhow::bail!(
            "gate: louvain must beat random modularity by {MOD_MARGIN} and keep \
             edge-cut within {CUT_FACTOR}x of metis on every synth graph \
             (see gate rows in BENCH_partition.json)"
        );
    }
    Ok(())
}
