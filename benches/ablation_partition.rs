//! Ablation: partitioner choice (METIS-multilevel vs BFS vs random).
//!
//! The paper's design rests on METIS producing dense communities with few
//! inter-community edges; this bench quantifies what that buys: edge cut →
//! p/s message bytes → communication time → end-to-end parallel epoch
//! time, plus any accuracy effect.
//!
//! Env knobs: CGCN_BENCH_EPOCHS (default 25), CGCN_BENCH_SCALE (0.25).

use cgcn::config::HyperParams;
use cgcn::coordinator::{AdmmOptions, AdmmTrainer, Workspace};
use cgcn::data::synth;
use cgcn::partition::Method;
use cgcn::runtime::{default_backend, ComputeBackend};
use std::sync::Arc;

fn env_or<T: std::str::FromStr>(key: &str, default: T) -> T {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    cgcn::util::logger::init();
    let epochs: usize = env_or("CGCN_BENCH_EPOCHS", 25);
    let scale: f64 = env_or("CGCN_BENCH_SCALE", 0.25);
    let backend = default_backend();
    eprintln!("ablation_partition: backend = {}", backend.name());

    let ds = synth::generate(&synth::AMAZON_PHOTO, scale, 17);
    let mut hp = HyperParams::for_dataset("synth-photo");
    hp.communities = 3;

    println!(
        "Partitioner ablation — parallel ADMM, {} , {epochs} epochs\n",
        ds.name
    );
    println!(
        "{:<10} {:>9} {:>9} {:>12} {:>10} {:>10} {:>10} {:>10}",
        "method", "edgecut", "cut %", "MB/epoch", "comm(s)", "train(s)", "total(s)", "test acc"
    );
    for method in [Method::Metis, Method::Bfs, Method::Random] {
        let ws = Arc::new(Workspace::build(&ds, &hp, method)?);
        let edgecut = ws.edgecut;
        let mut t = AdmmTrainer::new(ws, backend.clone(), AdmmOptions::for_mode(3))?;
        let rep = t.train(epochs, method.name())?;
        println!(
            "{:<10} {:>9} {:>8.1}% {:>12.2} {:>10.2} {:>10.2} {:>10.2} {:>10.3}",
            method.name(),
            edgecut,
            100.0 * edgecut as f64 / ds.graph.num_edges() as f64,
            rep.total_bytes() as f64 / rep.epochs.len() as f64 / 1e6,
            rep.total_comm(),
            rep.total_train(),
            rep.total_virtual(),
            rep.final_test_acc()
        );
    }
    Ok(())
}
