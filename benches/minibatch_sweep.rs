//! Mini-batch (Cluster-GCN) vs full-batch sweep: epoch time and peak
//! dense-activation rows for each (clusters, batch-clusters) point, with
//! the accuracy trajectory against the full-batch Adam GCN baseline and
//! parallel ADMM.
//!
//! Writes `BENCH_minibatch.json`. Claims under test:
//!
//! - per-step dense activations are bounded by the batch's node count
//!   (≈ q/c · n·(1+ε)), decoupling training memory from graph size —
//!   `peak_activation_rows` is measured, not derived;
//! - the mini-batch path lands within ~2 accuracy points of full-batch
//!   Adam at the same epoch budget (Cluster-GCN's empirical claim).
//!
//! Env knobs: CGCN_BENCH_EPOCHS (default 40), CGCN_BENCH_SCALE (0.25).

use cgcn::baselines::{BaselineTrainer, ClusterGcnOptions, ClusterGcnTrainer, Optimizer};
use cgcn::config::HyperParams;
use cgcn::coordinator::{AdmmOptions, AdmmTrainer, Workspace};
use cgcn::data::synth;
use cgcn::metrics::RunReport;
use cgcn::partition::Method;
use cgcn::runtime::{default_backend, ComputeBackend};
use cgcn::util::json::Json;
use std::sync::Arc;

fn env_or<T: std::str::FromStr>(key: &str, default: T) -> T {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Mean per-epoch training time (excludes evaluation).
fn mean_epoch_s(rep: &RunReport) -> f64 {
    rep.total_train() / rep.epochs.len().max(1) as f64
}

/// Test-accuracy trajectory, thinned to every 5th epoch (plus the last).
fn trajectory(rep: &RunReport) -> Json {
    let last = rep.epochs.len().saturating_sub(1);
    Json::arr(
        rep.epochs
            .iter()
            .filter(|e| e.epoch % 5 == 0 || e.epoch == last)
            .map(|e| {
                Json::obj(vec![
                    ("epoch", Json::num(e.epoch as f64)),
                    ("test_acc", Json::num(e.test_acc)),
                ])
            })
            .collect(),
    )
}

fn main() -> anyhow::Result<()> {
    cgcn::util::logger::init();
    let epochs: usize = env_or("CGCN_BENCH_EPOCHS", 40);
    let scale: f64 = env_or("CGCN_BENCH_SCALE", 0.25);
    let backend = default_backend();
    eprintln!("minibatch_sweep: backend = {}", backend.name());

    let spec = synth::AMAZON_COMPUTERS;
    let ds = Arc::new(synth::generate(&spec, scale, 17));
    let hp = HyperParams::for_dataset(spec.name);
    let n = ds.n();

    // Full-batch Adam baseline (every dense activation spans the padded
    // global row count — the memory floor mini-batching removes).
    let mut hp_fb = hp.clone();
    hp_fb.communities = 1;
    let ws_fb = Arc::new(Workspace::build(&ds, &hp_fb, Method::Metis)?);
    let full_rows = ws_fb.n_glob;
    let mut adam = BaselineTrainer::new(ws_fb, backend.clone(), Optimizer::parse("adam", None)?)?;
    let adam_rep = adam.train(epochs)?;
    println!(
        "full-batch adam:   {:>7} act rows  {:>9.4}s/epoch  final test {:.3}  best {:.3}",
        full_rows,
        mean_epoch_s(&adam_rep),
        adam_rep.final_test_acc(),
        adam_rep.best_test_acc()
    );

    // Parallel ADMM reference trajectory (paper's method, m = 3).
    let mut hp_admm = hp.clone();
    hp_admm.communities = 3;
    let ws_admm = Arc::new(Workspace::build(&ds, &hp_admm, Method::Metis)?);
    let mut admm = AdmmTrainer::new(ws_admm, backend.clone(), AdmmOptions::for_mode(3))?;
    let admm_rep = admm.train(epochs, "admm-parallel")?;
    println!(
        "admm m=3:          {:>7} act rows  {:>9.4}s/epoch  final test {:.3}  best {:.3}",
        full_rows,
        mean_epoch_s(&admm_rep),
        admm_rep.final_test_acc(),
        admm_rep.best_test_acc()
    );

    // Mini-batch sweep: c fine clusters, q grouped per step. The serve
    // workspace (hp.communities) is reused for evaluation only.
    let mut hp_mb = hp.clone();
    hp_mb.communities = 3;
    let ws_mb = Arc::new(Workspace::build(&ds, &hp_mb, Method::Metis)?);
    let mut rows_json = Vec::new();
    for (clusters, batch_clusters) in [(8usize, 2usize), (16, 4), (32, 4), (32, 8)] {
        let opts = ClusterGcnOptions {
            clusters,
            batch_clusters,
            method: Method::Metis,
        };
        let mut t = ClusterGcnTrainer::new(
            ds.clone(),
            ws_mb.clone(),
            backend.clone(),
            Optimizer::parse("adam", None)?,
            opts,
        )?;
        let rep = t.train(epochs)?;
        let peak = t.peak_batch_nodes();
        let gap = adam_rep.final_test_acc() - rep.final_test_acc();
        println!(
            "cluster-gcn c={clusters:<3} q={batch_clusters}: {:>7} act rows  {:>9.4}s/epoch  final test {:.3}  best {:.3}  gap vs adam {:+.3}",
            peak,
            mean_epoch_s(&rep),
            rep.final_test_acc(),
            rep.best_test_acc(),
            gap
        );
        rows_json.push(Json::obj(vec![
            ("clusters", Json::num(clusters as f64)),
            ("batch_clusters", Json::num(batch_clusters as f64)),
            ("peak_activation_rows", Json::num(peak as f64)),
            ("epoch_s_mean", Json::num(mean_epoch_s(&rep))),
            ("final_test_acc", Json::num(rep.final_test_acc())),
            ("best_test_acc", Json::num(rep.best_test_acc())),
            ("final_train_acc", Json::num(rep.final_train_acc())),
            ("acc_gap_vs_full_batch", Json::num(gap)),
            ("trajectory", trajectory(&rep)),
        ]));
    }

    let json = Json::obj(vec![
        ("bench", Json::str("minibatch_sweep")),
        ("dataset", Json::str(&ds.name)),
        ("n", Json::num(n as f64)),
        ("epochs", Json::num(epochs as f64)),
        (
            "full_batch",
            Json::obj(vec![
                ("method", Json::str("adam")),
                ("peak_activation_rows", Json::num(full_rows as f64)),
                ("epoch_s_mean", Json::num(mean_epoch_s(&adam_rep))),
                ("final_test_acc", Json::num(adam_rep.final_test_acc())),
                ("best_test_acc", Json::num(adam_rep.best_test_acc())),
                ("trajectory", trajectory(&adam_rep)),
            ]),
        ),
        (
            "admm",
            Json::obj(vec![
                ("method", Json::str("admm-parallel-m3")),
                ("final_test_acc", Json::num(admm_rep.final_test_acc())),
                ("best_test_acc", Json::num(admm_rep.best_test_acc())),
                ("trajectory", trajectory(&admm_rep)),
            ]),
        ),
        ("minibatch", Json::arr(rows_json)),
    ]);
    std::fs::write("BENCH_minibatch.json", json.to_pretty() + "\n")?;
    println!("(wrote BENCH_minibatch.json)");
    Ok(())
}
