//! Serving-throughput bench: sweeps server handler threads × micro-batch
//! window against a closed-loop load generator (clients = threads) and
//! records qps + p50/p99 latency to `BENCH_serve.json` — the perf
//! trajectory for the inference half of the system.
//!
//! ```sh
//! cargo bench --bench serve_throughput
//! ```
//!
//! What to expect: qps grows with handler threads (each client is
//! closed-loop, so concurrency is the offered load) while the batcher
//! stays a single thread — micro-batching coalesces the concurrent
//! queries into one backend batch per window, so the compute cost per
//! query *falls* as load rises. Latency p50 sits near the batch window;
//! window 0 shows the un-batched floor.

use cgcn::config::HyperParams;
use cgcn::coordinator::Workspace;
use cgcn::data::synth;
use cgcn::partition::Method;
use cgcn::runtime::NativeBackend;
use cgcn::serve::{loadgen, serve, InferenceSession, LoadgenOpts, ServeOptions};
use cgcn::tensor::Matrix;
use cgcn::util::json::Json;
use cgcn::util::rng::Rng;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    cgcn::util::logger::init();

    // Amazon-Photo-like graph at the bench scale (n=1913, F=745), 3
    // communities; weights are Glorot — serving cost is independent of
    // the values, so no training in the loop.
    let ds = synth::generate(&synth::AMAZON_PHOTO, 0.25, 17);
    let hp = HyperParams {
        communities: 3,
        ..HyperParams::for_dataset("synth-photo")
    };
    let ws = Arc::new(Workspace::build(&ds, &hp, Method::Metis)?);
    let mut rng = Rng::new(7);
    let w: Vec<Matrix> = (1..=ws.layers)
        .map(|l| Matrix::glorot(ws.dims[l - 1], ws.dims[l], &mut rng))
        .collect();

    let threads_sweep = [1usize, 2, 4, 8];
    let window_sweep_us = [0u64, 200, 1000];
    let requests_per_client = 150usize;
    let nodes_per_query = 4usize;

    println!(
        "{:>7} {:>9} {:>7} {:>9} {:>9} {:>9} {:>8} {:>9}",
        "threads", "window", "clients", "qps", "p50", "p99", "batches", "req/batch"
    );
    let mut rows_json = Vec::new();
    let mut qps_1thread = vec![0.0f64; window_sweep_us.len()];
    for &t in &threads_sweep {
        for (wi, &window_us) in window_sweep_us.iter().enumerate() {
            let mut session =
                InferenceSession::new(ws.clone(), Arc::new(NativeBackend::new()), w.clone())?;
            session.warm_all()?;
            let handle = serve(
                session,
                &ServeOptions {
                    addr: "127.0.0.1:0".to_string(),
                    threads: t,
                    batch_window_us: window_us,
                    max_batch: 256,
                },
            )?;
            let addr = handle.addr().to_string();
            let report = loadgen::run(
                &addr,
                ws.n,
                &LoadgenOpts {
                    clients: t,
                    requests_per_client,
                    nodes_per_query,
                    seed: 17,
                },
            )?;
            let (requests, _nodes, batches) = handle.counters();
            handle.stop();
            if t == 1 {
                qps_1thread[wi] = report.qps;
            }
            let req_per_batch = requests as f64 / (batches.max(1)) as f64;
            println!(
                "{:>7} {:>7}us {:>7} {:>9.0} {:>7.2}ms {:>7.2}ms {:>8} {:>9.2}",
                t,
                window_us,
                t,
                report.qps,
                report.latency.p50 * 1e3,
                report.latency.p99 * 1e3,
                batches,
                req_per_batch
            );
            rows_json.push(Json::obj(vec![
                ("threads", Json::num(t as f64)),
                ("window_us", Json::num(window_us as f64)),
                ("clients", Json::num(t as f64)),
                ("requests", Json::num(report.requests as f64)),
                ("nodes_per_query", Json::num(nodes_per_query as f64)),
                ("qps", Json::num(report.qps)),
                ("p50_ms", Json::num(report.latency.p50 * 1e3)),
                ("p99_ms", Json::num(report.latency.p99 * 1e3)),
                ("mean_ms", Json::num(report.latency.mean * 1e3)),
                ("batches", Json::num(batches as f64)),
                ("requests_per_batch", Json::num(req_per_batch)),
                ("qps_speedup_vs_1thread", Json::num(report.qps / qps_1thread[wi].max(1e-9))),
            ]));
        }
    }
    let host_threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let out = Json::obj(vec![
        ("bench", Json::str("serve_throughput")),
        ("dataset", Json::str(&format!("synth-photo n={}", ws.n))),
        ("host_threads", Json::num(host_threads as f64)),
        ("requests_per_client", Json::num(requests_per_client as f64)),
        ("rows", Json::arr(rows_json)),
    ]);
    std::fs::write("BENCH_serve.json", out.to_pretty() + "\n")?;
    println!("(wrote BENCH_serve.json; host has {host_threads} hardware threads)");
    Ok(())
}
