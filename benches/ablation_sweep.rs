//! Ablations over the design choices DESIGN.md calls out:
//!
//! 1. link bandwidth sweep (100 Mbit/s .. 50 Gbit/s) — where the paper's
//!    speedup claim lives as a function of network quality;
//! 2. ρ = ν sweep — sensitivity of ADMM convergence to the penalty scale
//!    (the paper tunes 1e-3 vs 1e-4 per dataset);
//! 3. scheduler ablation — own-block Gauss-Seidel anchoring vs pure Jacobi
//!    and the paper-literal centralised W update vs the distributed
//!    row-block reduction.
//!
//! Env knobs: CGCN_BENCH_EPOCHS (default 25), CGCN_BENCH_SCALE (0.25).

use cgcn::config::HyperParams;
use cgcn::coordinator::{AdmmOptions, AdmmTrainer, LinkModel, Workspace};
use cgcn::data::synth;
use cgcn::partition::Method;
use cgcn::runtime::{default_backend, ComputeBackend};
use std::sync::Arc;

fn env_or<T: std::str::FromStr>(key: &str, default: T) -> T {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    cgcn::util::logger::init();
    let epochs: usize = env_or("CGCN_BENCH_EPOCHS", 25);
    let scale: f64 = env_or("CGCN_BENCH_SCALE", 0.25);
    let backend = default_backend();
    eprintln!("ablation_sweep: backend = {}", backend.name());
    let ds = synth::generate(&synth::AMAZON_PHOTO, scale, 17);
    let hp = HyperParams::for_dataset("synth-photo");

    // ---- 1. bandwidth sweep ------------------------------------------------
    println!("=== link bandwidth sweep (parallel ADMM M=3 vs serial, {epochs} epochs) ===");
    let serial = {
        let mut hp_s = hp.clone();
        hp_s.communities = 1;
        let ws = Arc::new(Workspace::build(&ds, &hp_s, Method::Metis)?);
        AdmmTrainer::new(ws, backend.clone(), AdmmOptions::for_mode(1))?.train(epochs, "serial")?
    };
    println!(
        "{:<12} {:>10} {:>10} {:>10} {:>9}",
        "link", "comm(s)", "train(s)", "total(s)", "speedup"
    );
    println!(
        "{:<12} {:>10.2} {:>10.2} {:>10.2} {:>9}",
        "serial", 0.0, serial.total_train(), serial.total_virtual(), "-"
    );
    for mbps in [100.0, 1_000.0, 10_000.0, 50_000.0] {
        let mut hp_p = hp.clone();
        hp_p.communities = 3;
        let ws = Arc::new(Workspace::build(&ds, &hp_p, Method::Metis)?);
        let mut opts = AdmmOptions::for_mode(3);
        opts.link = LinkModel::new(mbps, 100.0);
        let rep = AdmmTrainer::new(ws, backend.clone(), opts)?.train(epochs, "parallel")?;
        println!(
            "{:<12} {:>10.2} {:>10.2} {:>10.2} {:>8.2}x",
            format!("{}M", mbps as u64),
            rep.total_comm(),
            rep.total_train(),
            rep.total_virtual(),
            serial.total_virtual() / rep.total_virtual()
        );
    }

    // ---- 2. rho/nu sweep -----------------------------------------------------
    println!("\n=== rho = nu sweep (serial ADMM, {epochs} epochs) ===");
    println!("{:<10} {:>10} {:>10} {:>10}", "rho=nu", "loss", "train acc", "test acc");
    for rho in [1e-2f32, 1e-3, 1e-4, 1e-5] {
        let mut hp_r = hp.clone();
        hp_r.communities = 1;
        hp_r.rho = rho;
        hp_r.nu = rho;
        let ws = Arc::new(Workspace::build(&ds, &hp_r, Method::Metis)?);
        let rep = AdmmTrainer::new(ws, backend.clone(), AdmmOptions::for_mode(1))?
            .train(epochs, "admm")?;
        let last = rep.epochs.last().unwrap();
        println!(
            "{:<10.0e} {:>10.4} {:>10.3} {:>10.3}",
            rho, last.loss, last.train_acc, last.test_acc
        );
    }

    // ---- 3. scheduler ablation -----------------------------------------------
    println!("\n=== scheduler ablation (parallel M=3, {epochs} epochs) ===");
    println!(
        "{:<26} {:>10} {:>10} {:>10} {:>10}",
        "variant", "train(s)", "comm(s)", "test acc", "loss"
    );
    let variants: [(&str, Box<dyn Fn(&mut AdmmOptions)>); 3] = [
        ("default (GS + dist-W)", Box::new(|_o: &mut AdmmOptions| {})),
        ("pure Jacobi anchor", Box::new(|o: &mut AdmmOptions| o.gauss_seidel = false)),
        ("central W (paper lit.)", Box::new(|o: &mut AdmmOptions| o.central_w = true)),
    ];
    for (name, tweak) in &variants {
        let mut hp_p = hp.clone();
        hp_p.communities = 3;
        let ws = Arc::new(Workspace::build(&ds, &hp_p, Method::Metis)?);
        let mut opts = AdmmOptions::for_mode(3);
        tweak(&mut opts);
        let rep = AdmmTrainer::new(ws, backend.clone(), opts)?.train(epochs, name)?;
        let last = rep.epochs.last().unwrap();
        println!(
            "{:<26} {:>10.2} {:>10.2} {:>10.3} {:>10.4}",
            name,
            rep.total_train(),
            rep.total_comm(),
            last.test_acc,
            last.loss
        );
    }
    Ok(())
}
