//! Micro-benchmarks of the substrate hot paths: CSR SpMM (the L3 sparse
//! half of every subproblem), serial-vs-pooled SpMM/matmul scaling across
//! thread counts, backend dispatch overhead, wire serialisation,
//! gather/scatter, and the partitioner itself.
//!
//! The 1/2/4/8-thread section writes `BENCH_parallel.json` so the perf
//! trajectory records *real* (wall-clock) parallel speedups, not just the
//! virtual-time model. These feed the EXPERIMENTS.md §Perf roofline
//! discussion: SpMM should be memory-bound (≈ 2 flops/4 bytes of X per
//! nonzero), dispatch should sit well under one percent of a realistic
//! matmul.

use cgcn::bench::{bench, fmt_secs, gflops, report_row, section, BenchOpts};
use cgcn::config::HyperParams;
use cgcn::coordinator::Workspace;
use cgcn::data::synth;
use cgcn::partition::{partition, Method};
use cgcn::runtime::{default_backend, ComputeBackend, NativeBackend};
use cgcn::tensor::Matrix;
use cgcn::util::json::Json;
use cgcn::util::rng::Rng;
use cgcn::util::wire::{Dec, Enc};

fn main() -> anyhow::Result<()> {
    cgcn::util::logger::init();
    let opts = BenchOpts::default();
    let ds = synth::generate(&synth::AMAZON_PHOTO, 0.25, 17);
    let a = ds.graph.normalized_adjacency();
    let mut rng = Rng::new(7);

    // ---- SpMM ----------------------------------------------------------------
    section("CSR SpMM (Ã × dense, n=1913, nnz≈60k)");
    for cols in [8usize, 64, 256, 745] {
        let x = Matrix::glorot(a.ncols(), cols, &mut rng);
        let s = bench(opts, || a.spmm(&x));
        let flops = 2.0 * a.nnz() as f64 * cols as f64;
        println!(
            "spmm cols={cols:<4}  {:>10}/iter  {:>7.2} GFLOP/s  {:>7.2} GB/s streamed",
            fmt_secs(s.p50),
            gflops(flops, s.p50),
            (a.nnz() * cols * 4) as f64 / s.p50 / 1e9
        );
    }

    // ---- serial vs pooled scaling -------------------------------------------
    section("parallel scaling (native backend, grain forced)");
    let threads_sweep = [1usize, 2, 4, 8];
    let spmm_x = Matrix::glorot(a.ncols(), 256, &mut rng);
    let mm_x = Matrix::glorot(1024, 745, &mut rng);
    let mm_w = Matrix::glorot(745, 256, &mut rng);
    let mut spmm_rows_json = Vec::new();
    let mut mm_rows_json = Vec::new();
    let mut spmm_serial_p50 = 0.0f64;
    let mut mm_serial_p50 = 0.0f64;
    for &t in &threads_sweep {
        let be = NativeBackend::with_grain(t, 0);
        let s_spmm = bench(opts, || be.spmm(&a, &spmm_x));
        let s_mm = bench(opts, || be.mm_nn(&mm_x, &mm_w).unwrap());
        if t == 1 {
            spmm_serial_p50 = s_spmm.p50;
            mm_serial_p50 = s_mm.p50;
        }
        println!(
            "threads={t}:  spmm(256 cols) {:>10}/iter ({:>5.2}x)   mm_nn 1024x745x256 {:>10}/iter ({:>5.2}x)",
            fmt_secs(s_spmm.p50),
            spmm_serial_p50 / s_spmm.p50,
            fmt_secs(s_mm.p50),
            mm_serial_p50 / s_mm.p50
        );
        spmm_rows_json.push(Json::obj(vec![
            ("threads", Json::num(t as f64)),
            ("cols", Json::num(256.0)),
            ("p50_s", Json::num(s_spmm.p50)),
            ("mean_s", Json::num(s_spmm.mean)),
            ("speedup", Json::num(spmm_serial_p50 / s_spmm.p50)),
        ]));
        mm_rows_json.push(Json::obj(vec![
            ("threads", Json::num(t as f64)),
            ("shape", Json::str("1024x745x256")),
            ("p50_s", Json::num(s_mm.p50)),
            ("mean_s", Json::num(s_mm.mean)),
            ("speedup", Json::num(mm_serial_p50 / s_mm.p50)),
        ]));
    }
    let host_threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let parallel_json = Json::obj(vec![
        ("bench", Json::str("micro_parallel")),
        ("host_threads", Json::num(host_threads as f64)),
        ("spmm_nnz", Json::num(a.nnz() as f64)),
        ("spmm", Json::arr(spmm_rows_json)),
        ("matmul", Json::arr(mm_rows_json)),
    ]);
    std::fs::write("BENCH_parallel.json", parallel_json.to_pretty() + "\n")?;
    println!("(wrote BENCH_parallel.json; host has {host_threads} hardware threads)");

    // ---- SpMM transpose & blocks ----------------------------------------------
    section("CSR ops");
    report_row("transpose (nnz≈60k)", &bench(opts, || a.transpose()));
    let part = partition(&ds.graph, 3, Method::Metis, 17);
    report_row(
        "metis partition (n=1913, m=3)",
        &bench(
            BenchOpts {
                warmup_iters: 1,
                iters: 5,
            },
            || partition(&ds.graph, 3, Method::Metis, 17),
        ),
    );
    let _ = part;

    // ---- wire -------------------------------------------------------------------
    section("wire serialisation (f32 matrix 768x256 = 0.79 MB)");
    let mat = Matrix::glorot(768, 256, &mut rng);
    report_row(
        "encode",
        &bench(opts, || {
            let mut e = Enc::with_capacity(mat.data().len() * 4 + 16);
            e.f32s(mat.data());
            e.into_bytes()
        }),
    );
    let mut e = Enc::new();
    e.f32s(mat.data());
    let bytes = e.into_bytes();
    report_row(
        "decode",
        &bench(opts, || Dec::new(&bytes).f32s().unwrap()),
    );

    // ---- backend dispatch ------------------------------------------------------
    let backend = default_backend();
    section("backend kernel dispatch (n=768 shapes)");
    println!("backend: {}", backend.name());
    let hp = HyperParams::for_dataset("synth-photo");
    let hp3 = HyperParams {
        communities: 3,
        ..hp
    };
    let ws = Workspace::build(&ds, &hp3, Method::Metis)?;
    let x = Matrix::glorot(768, 745, &mut rng);
    let w = Matrix::glorot(745, 256, &mut rng);
    backend.warmup(&[ws.sig_nab("mm_nn", 768, 745, 256)])?;
    let s = bench(opts, || backend.mm_nn(&x, &w).unwrap());
    let flops = 2.0 * 768.0 * 745.0 * 256.0;
    println!(
        "mm_nn 768x745x256   {:>10}/call  {:>7.2} GFLOP/s (incl. dispatch)",
        fmt_secs(s.p50),
        gflops(flops, s.p50)
    );
    // Dispatch floor: smallest kernel in the plan.
    backend.warmup(&[ws.sig_nc("out_phi", 768, 8)])?;
    let z8 = Matrix::zeros(768, 8);
    let s3 = bench(opts, || {
        backend.out_phi(&z8, &z8, &z8, 1.0).unwrap()
    });
    report_row("dispatch floor (out_phi 768x8)", &s3);

    // ---- gather/scatter --------------------------------------------------------
    section("workspace gather/scatter (m=3, 256 cols)");
    let per: Vec<Matrix> = (0..3).map(|_| Matrix::glorot(ws.n_pad, 256, &mut rng)).collect();
    report_row("gather", &bench(opts, || ws.gather(&per)));
    let glob = ws.gather(&per);
    report_row("scatter", &bench(opts, || ws.scatter(&glob)));

    // ---- roofline note ----------------------------------------------------------
    println!(
        "\nroofline context: single-core DRAM stream ≈ 10-20 GB/s ⇒ SpMM at\n\
         2 flops per 4 streamed bytes tops out near 5-10 GFLOP/s; the pooled\n\
         row-block kernels scale that with cores until the memory bus saturates."
    );
    Ok(())
}
