//! Micro-benchmarks of the substrate hot paths: CSR SpMM (the L3 sparse
//! half of every subproblem), artifact dispatch overhead, wire
//! serialisation, gather/scatter, and the partitioner itself.
//!
//! These feed the EXPERIMENTS.md §Perf roofline discussion: SpMM should be
//! memory-bound (≈ 2 flops/4 bytes of X per nonzero), artifact dispatch
//! should sit well under one percent of a realistic matmul.

use cgcn::bench::{bench, fmt_secs, gflops, report_row, section, BenchOpts};
use cgcn::config::HyperParams;
use cgcn::coordinator::Workspace;
use cgcn::data::synth;
use cgcn::graph::Csr;
use cgcn::partition::{partition, Method};
use cgcn::runtime::{Engine, In};
use cgcn::tensor::Matrix;
use cgcn::util::rng::Rng;
use cgcn::util::wire::{Dec, Enc};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    cgcn::util::logger::init();
    let opts = BenchOpts::default();
    let ds = synth::generate(&synth::AMAZON_PHOTO, 0.25, 17);
    let a = ds.graph.normalized_adjacency();
    let mut rng = Rng::new(7);

    // ---- SpMM ----------------------------------------------------------------
    section("CSR SpMM (Ã × dense, n=1913, nnz≈60k)");
    for cols in [8usize, 64, 256, 745] {
        let x = Matrix::glorot(a.ncols(), cols, &mut rng);
        let s = bench(opts, || a.spmm(&x));
        let flops = 2.0 * a.nnz() as f64 * cols as f64;
        println!(
            "spmm cols={cols:<4}  {:>10}/iter  {:>7.2} GFLOP/s  {:>7.2} GB/s streamed",
            fmt_secs(s.p50),
            gflops(flops, s.p50),
            (a.nnz() * cols * 4) as f64 / s.p50 / 1e9
        );
    }

    // ---- SpMM transpose & blocks ----------------------------------------------
    section("CSR ops");
    report_row("transpose (nnz≈60k)", &bench(opts, || a.transpose()));
    let part = partition(&ds.graph, 3, Method::Metis, 17);
    report_row(
        "metis partition (n=1913, m=3)",
        &bench(
            BenchOpts {
                warmup_iters: 1,
                iters: 5,
            },
            || partition(&ds.graph, 3, Method::Metis, 17),
        ),
    );
    let _ = part;

    // ---- wire -------------------------------------------------------------------
    section("wire serialisation (f32 matrix 768x256 = 0.79 MB)");
    let mat = Matrix::glorot(768, 256, &mut rng);
    report_row(
        "encode",
        &bench(opts, || {
            let mut e = Enc::with_capacity(mat.data().len() * 4 + 16);
            e.f32s(mat.data());
            e.into_bytes()
        }),
    );
    let mut e = Enc::new();
    e.f32s(mat.data());
    let bytes = e.into_bytes();
    report_row(
        "decode",
        &bench(opts, || Dec::new(&bytes).f32s().unwrap()),
    );

    if !Engine::available() {
        eprintln!("\n(artifacts missing — skipping runtime micro-benches)");
        return Ok(());
    }
    let engine = Arc::new(Engine::load(&Engine::default_dir())?);

    // ---- artifact dispatch ---------------------------------------------------
    section("artifact execution (n=768 shapes)");
    let hp = HyperParams::for_dataset("synth-photo");
    let hp3 = HyperParams {
        communities: 3,
        ..hp
    };
    let ws = Workspace::build(&ds, &hp3, Method::Metis)?;
    let x = Matrix::glorot(768, 745, &mut rng);
    let w = Matrix::glorot(745, 256, &mut rng);
    let sig = ws.sig_nab("mm_nn", 768, 745, 256);
    engine.warmup(&[sig.clone()])?;
    let s = bench(opts, || {
        engine.exec(&sig, &[In::Mat(&x), In::Mat(&w)]).unwrap()
    });
    let flops = 2.0 * 768.0 * 745.0 * 256.0;
    println!(
        "mm_nn 768x745x256   {:>10}/call  {:>7.2} GFLOP/s (incl. marshal)",
        fmt_secs(s.p50),
        gflops(flops, s.p50)
    );
    // Prepared-literal variant (no per-call marshal of the big operand).
    let prep = engine.prepare(&x)?;
    let s2 = bench(opts, || {
        engine.exec(&sig, &[In::Prep(&prep), In::Mat(&w)]).unwrap()
    });
    println!(
        "  + prepared lhs    {:>10}/call  {:>7.2} GFLOP/s",
        fmt_secs(s2.p50),
        gflops(flops, s2.p50)
    );
    // Dispatch floor: smallest artifact in the plan.
    let small_sig = ws.sig_nc("out_phi", 768, 8);
    engine.warmup(&[small_sig.clone()])?;
    let z8 = Matrix::zeros(768, 8);
    let s3 = bench(opts, || {
        engine
            .exec(
                &small_sig,
                &[In::Mat(&z8), In::Mat(&z8), In::Mat(&z8), In::Scalar(1.0)],
            )
            .unwrap()
    });
    report_row("dispatch floor (out_phi 768x8)", &s3);

    // ---- gather/scatter --------------------------------------------------------
    section("workspace gather/scatter (m=3, 256 cols)");
    let per: Vec<Matrix> = (0..3).map(|_| Matrix::glorot(ws.n_pad, 256, &mut rng)).collect();
    report_row("gather", &bench(opts, || ws.gather(&per)));
    let glob = ws.gather(&per);
    report_row("scatter", &bench(opts, || ws.scatter(&glob)));

    // ---- roofline note ----------------------------------------------------------
    let c = Csr::from_triplets(4, 4, &[(0, 0, 1.0)]);
    let _ = c;
    println!(
        "\nroofline context: single-core DRAM stream ≈ 10-20 GB/s ⇒ SpMM at\n\
         2 flops per 4 streamed bytes tops out near 5-10 GFLOP/s; dense MXU-\n\
         style matmul through XLA reaches 60-90 GFLOP/s on this core."
    );
    Ok(())
}
