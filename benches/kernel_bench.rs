//! Kernel runtime benchmark: persistent fork-join pool vs spawn-per-op.
//!
//! Sweeps every pooled kernel (dense matmuls, nnz-balanced SpMM, the
//! elementwise residual/prox family, softmax-xent, FISTA) over
//! op-threads ∈ {1,2,4,8} under both executors, then times end-to-end
//! ADMM and Cluster-GCN epochs the same way. Results land in
//! `BENCH_kernels.json`; the calibrated per-op thresholds in
//! `OpGrains::calibrated()` cite these numbers.
//!
//! Env knobs:
//!   CGCN_BENCH_QUICK=1  — CI quick mode: fewer iters/threads/shapes,
//!                         epoch section trimmed to the 8-thread A/B pair.
//!   CGCN_BENCH_GATE=1   — exit non-zero if the pooled executor is slower
//!                         than spawn-per-op (>10% to absorb timer noise)
//!                         at 8 threads on the reference shapes.
//!   CGCN_BENCH_EPOCHS   — timed epochs per end-to-end cell.
//!   CGCN_BENCH_OBS_GATE=1 — A/B the CGCN_OBS telemetry gate in-process
//!                         on pooled ADMM epochs; exit non-zero if
//!                         enabling telemetry costs more than 5%.
//!   CGCN_BENCH_RUNTIME_GATE=1 — exit non-zero if the shared work-stealing
//!                         runtime loses (>10% margin) to the legacy dual
//!                         pools on the 8-thread end-to-end ADMM epoch.
//!   CGCN_BENCH_SIMD_GATE=1 — A/B the 8-wide AVX matmul microkernel vs the
//!                         scalar inner loop per dense op on the large
//!                         reference shapes; exit non-zero if SIMD loses
//!                         (>10% margin) on hardware that detects AVX.

use cgcn::bench::{bench, fmt_secs, section, BenchOpts};
use cgcn::config::HyperParams;
use cgcn::coordinator::{AdmmOptions, AdmmTrainer, ExecMode, Workspace};
use cgcn::data::synth;
use cgcn::partition::Method;
use cgcn::runtime::{ComputeBackend, NativeBackend};
use cgcn::tensor::Matrix;
use cgcn::util::json::Json;
use cgcn::util::pool::Runtime;
use cgcn::util::rng::Rng;
use std::sync::Arc;
use std::time::Instant;

fn env_or<T: std::str::FromStr>(key: &str, default: T) -> T {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn env_flag(key: &str) -> bool {
    std::env::var(key).map(|v| v == "1" || v == "true").unwrap_or(false)
}

/// One measured (op, shape, threads, executor) cell.
struct Cell {
    op: &'static str,
    shape: String,
    threads: usize,
    exec: &'static str,
    p50: f64,
    mean: f64,
}

impl Cell {
    fn json(&self) -> Json {
        Json::obj(vec![
            ("op", Json::str(self.op)),
            ("shape", Json::str(&self.shape)),
            ("threads", Json::num(self.threads as f64)),
            ("exec", Json::str(self.exec)),
            ("p50_s", Json::num(self.p50)),
            ("mean_s", Json::num(self.mean)),
        ])
    }
}

fn main() -> anyhow::Result<()> {
    cgcn::util::logger::init();
    let quick = env_flag("CGCN_BENCH_QUICK");
    let gate = env_flag("CGCN_BENCH_GATE");
    let opts = if quick {
        BenchOpts {
            warmup_iters: 1,
            iters: 7,
        }
    } else {
        BenchOpts::default()
    };
    let threads_sweep: &[usize] = if quick { &[1, 8] } else { &[1, 2, 4, 8] };
    let host_threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    println!(
        "kernel_bench: host has {host_threads} hardware threads{}",
        if quick { " (quick mode)" } else { "" }
    );

    // Fixture: the synthetic photo graph drives SpMM (real Ã sparsity and
    // the skewed row-nnz distribution the balanced chunking targets); the
    // dense shapes mirror the layer-1 subproblem (n × F → n × H).
    let ds = Arc::new(synth::generate(&synth::AMAZON_PHOTO, 0.25, 17));
    let a = ds.graph.normalized_adjacency();
    let n = a.ncols();
    let mut rng = Rng::new(7);
    let x_f = Matrix::glorot(n, 745, &mut rng); // features
    let w1 = Matrix::glorot(745, 256, &mut rng);
    let h = Matrix::glorot(n, 256, &mut rng); // hidden activations
    let g = Matrix::glorot(n, 256, &mut rng); // same-shape gradient
    let z8 = Matrix::glorot(n, 8, &mut rng); // logit-width block
    let y8 = Matrix::zeros(n, 8);
    let mask: Vec<f32> = (0..n).map(|i| if i % 3 == 0 { 1.0 } else { 0.0 }).collect();
    let denom = mask.iter().sum::<f32>().max(1.0);

    // ---- kernel sweep: op × shape × threads × executor --------------------
    section("kernel sweep (grain forced to 0 so every cell actually forks)");
    let mut cells: Vec<Cell> = Vec::new();
    let mut ref_pool_p50 = f64::NAN; // reference cells for the CI gate
    let mut ref_spawn_p50 = f64::NAN;
    for &t in threads_sweep {
        for spawn in [false, true] {
            if spawn && t == 1 {
                continue; // t=1 never dispatches; identical to pooled
            }
            let be = if spawn {
                NativeBackend::with_spawn_grain(t, 0)
            } else {
                NativeBackend::with_grain(t, 0)
            };
            let exec = if spawn { "spawn" } else { "pool" };
            let mut run = |op: &'static str, shape: String, f: &mut dyn FnMut()| {
                let s = bench(opts, f);
                println!(
                    "{exec:<5} t={t}  {op:<15} {shape:<16} {:>10}/iter",
                    fmt_secs(s.p50)
                );
                cells.push(Cell {
                    op,
                    shape,
                    threads: t,
                    exec,
                    p50: s.p50,
                    mean: s.mean,
                });
                s.p50
            };
            run("mm_nn", format!("{n}x745x256"), &mut || {
                be.mm_nn(&x_f, &w1).unwrap();
            });
            run("mm_tn", format!("745x{n}x256"), &mut || {
                be.mm_tn(&x_f, &h).unwrap();
            });
            run("mm_bt", format!("{n}x256x745"), &mut || {
                be.mm_bt(&h, &w1).unwrap();
            });
            run("spmm", format!("nnz{}x256", a.nnz()), &mut || {
                be.spmm(&a, &h);
            });
            let p50 = run("hidden_residual", format!("{n}x256"), &mut || {
                be.hidden_residual(&h, &g, 1.0).unwrap();
            });
            // Reference cells for the CI gate: the elementwise family is
            // where spawn overhead dominates, so a pooled regression shows
            // up here first.
            if t == 8 {
                if spawn {
                    ref_spawn_p50 = p50;
                } else {
                    ref_pool_p50 = p50;
                }
            }
            run("z_combine", format!("{n}x256"), &mut || {
                be.z_combine(&h, &g, &g, 1.0, 1.0).unwrap();
            });
            run("xent_loss", format!("{n}x8"), &mut || {
                be.xent_loss(&z8, &y8, &mask, denom).unwrap();
            });
            if !quick {
                run("zl_fista(10)", format!("{n}x8"), &mut || {
                    be.zl_fista(&z8, &y8, &y8, &mask, &z8, 1.0, denom, 10)
                        .unwrap();
                });
            }
        }
    }

    // ---- simd vs scalar microkernel A/B -----------------------------------
    // Serial backends isolate the inner-loop change from dispatch effects;
    // the shapes are the large dense trainer shapes where the roofline
    // lift must show. Results are bitwise identical by construction
    // (DESIGN.md §12), so this measures speed only.
    section("simd A/B: 8-wide AVX microkernel vs scalar inner loop (serial backend)");
    let simd_gate = env_flag("CGCN_BENCH_SIMD_GATE");
    let simd_detected = cgcn::tensor::simd::detected();
    let mut simd_rows: Vec<Json> = Vec::new();
    let mut simd_ok = true;
    {
        let scalar_be = NativeBackend::new().with_simd(false);
        let simd_be = NativeBackend::new().with_simd(true);
        let mut ab = |op: &'static str, shape: String, f: &mut dyn FnMut(&NativeBackend)| {
            let s_scalar = bench(opts, &mut || f(&scalar_be));
            let s_simd = bench(opts, &mut || f(&simd_be));
            let speedup = s_scalar.p50 / s_simd.p50;
            println!(
                "simd  {op:<15} {shape:<16} simd {:>10} vs scalar {:>10}  ({speedup:.2}x)",
                fmt_secs(s_simd.p50),
                fmt_secs(s_scalar.p50)
            );
            if s_simd.p50 > s_scalar.p50 * 1.10 {
                simd_ok = false;
            }
            simd_rows.push(Json::obj(vec![
                ("op", Json::str(op)),
                ("shape", Json::str(&shape)),
                ("simd_p50_s", Json::num(s_simd.p50)),
                ("scalar_p50_s", Json::num(s_scalar.p50)),
                ("speedup", Json::num(speedup)),
            ]));
        };
        ab("mm_nn", format!("{n}x745x256"), &mut |be| {
            be.mm_nn(&x_f, &w1).unwrap();
        });
        ab("mm_tn", format!("745x{n}x256"), &mut |be| {
            be.mm_tn(&x_f, &h).unwrap();
        });
        ab("mm_bt", format!("{n}x256x745"), &mut |be| {
            be.mm_bt(&h, &w1).unwrap();
        });
    }
    if !simd_detected {
        println!("(AVX not detected on this host; simd cells ran the scalar fallback)");
    }

    // ---- end-to-end epochs: ADMM + Cluster-GCN ---------------------------
    // Agent executor stays serial so the measurement isolates *kernel*
    // parallelism (the regime `--op-threads` controls); the A/B flips only
    // the executor behind the same backend trait.
    section("end-to-end epoch time (pool vs spawn, agent loop serial)");
    let epochs: usize = env_or("CGCN_BENCH_EPOCHS", if quick { 2 } else { 5 });
    let hp = HyperParams::for_dataset("synth-photo");
    let mut epoch_rows: Vec<Json> = Vec::new();
    let mut admm_pool8 = f64::NAN;
    let mut admm_spawn8 = f64::NAN;
    let epoch_threads: &[usize] = if quick { &[8] } else { threads_sweep };
    for &t in epoch_threads {
        for spawn in [false, true] {
            if spawn && t == 1 {
                continue;
            }
            let backend: Arc<dyn ComputeBackend> = if spawn {
                Arc::new(NativeBackend::with_spawn_threads(t))
            } else {
                Arc::new(NativeBackend::with_threads(t))
            };
            let exec = if spawn { "spawn" } else { "pool" };

            let mut hp_m = hp.clone();
            hp_m.communities = 3;
            let ws = Arc::new(Workspace::build(&ds, &hp_m, Method::Metis)?);
            let mut trainer =
                AdmmTrainer::new(ws, backend.clone(), AdmmOptions::for_mode(3))?;
            trainer.train(1, "warmup")?; // page in + fill the arena
            let t0 = Instant::now();
            trainer.train(epochs, "bench")?;
            let admm_s = t0.elapsed().as_secs_f64() / epochs as f64;

            let mut hp_fb = hp.clone();
            hp_fb.communities = 1;
            let ws_fb = Arc::new(Workspace::build(&ds, &hp_fb, Method::Metis)?);
            let mut cg = cgcn::baselines::ClusterGcnTrainer::new(
                ds.clone(),
                ws_fb,
                backend.clone(),
                cgcn::baselines::Optimizer::parse("adam", None)?,
                cgcn::baselines::ClusterGcnOptions::default(),
            )?;
            cg.train_epoch()?; // warmup
            let t0 = Instant::now();
            for _ in 0..epochs {
                cg.train_epoch()?;
            }
            let cg_s = t0.elapsed().as_secs_f64() / epochs as f64;

            println!(
                "{exec:<5} op-threads={t}:  admm {:>10}/epoch   cluster-gcn {:>10}/epoch",
                fmt_secs(admm_s),
                fmt_secs(cg_s)
            );
            if t == 8 {
                if spawn {
                    admm_spawn8 = admm_s;
                } else {
                    admm_pool8 = admm_s;
                }
            }
            epoch_rows.push(Json::obj(vec![
                ("trainer", Json::str("admm")),
                ("threads", Json::num(t as f64)),
                ("exec", Json::str(exec)),
                ("epoch_s", Json::num(admm_s)),
            ]));
            epoch_rows.push(Json::obj(vec![
                ("trainer", Json::str("cluster_gcn")),
                ("threads", Json::num(t as f64)),
                ("exec", Json::str(exec)),
                ("epoch_s", Json::num(cg_s)),
            ]));
        }
    }

    // ---- shared vs dual thread runtime (end-to-end, --exec threads) -------
    // The A/B behind `--runtime shared|dual`: dual is the legacy pair of
    // pools at the CLI defaults (agent Pool over communities, kernels
    // serial under --exec threads), shared is one 8-thread work-stealing
    // runtime carrying agent tasks and kernel forks alike. Dual idles
    // budget-minus-m cores during every kernel; shared lets blocked
    // agents' workers steal kernel chunks instead.
    section("runtime A/B: shared work-stealing vs dual pools (--exec threads, 8-thread budget)");
    let rt_gate = env_flag("CGCN_BENCH_RUNTIME_GATE");
    let rt_threads = 8usize;
    let time_admm_rt = |backend: Arc<dyn ComputeBackend>| -> anyhow::Result<f64> {
        let mut hp_m = hp.clone();
        hp_m.communities = 3;
        let ws = Arc::new(Workspace::build(&ds, &hp_m, Method::Metis)?);
        let mut o = AdmmOptions::for_mode(3);
        o.exec = ExecMode::Threads;
        o.threads = rt_threads;
        let mut trainer = AdmmTrainer::new(ws, backend, o)?;
        trainer.train(1, "rt-warmup")?;
        let t0 = Instant::now();
        trainer.train(epochs, "rt-bench")?;
        Ok(t0.elapsed().as_secs_f64() / epochs as f64)
    };
    let time_cg_rt = |backend: Arc<dyn ComputeBackend>| -> anyhow::Result<f64> {
        let mut hp_fb = hp.clone();
        hp_fb.communities = 1;
        let ws_fb = Arc::new(Workspace::build(&ds, &hp_fb, Method::Metis)?);
        let mut cg = cgcn::baselines::ClusterGcnTrainer::new(
            ds.clone(),
            ws_fb,
            backend,
            cgcn::baselines::Optimizer::parse("adam", None)?,
            cgcn::baselines::ClusterGcnOptions::default(),
        )?;
        cg.train_epoch()?; // warmup
        let t0 = Instant::now();
        for _ in 0..epochs {
            cg.train_epoch()?;
        }
        Ok(t0.elapsed().as_secs_f64() / epochs as f64)
    };
    // Dual, as `--runtime dual` resolves it: admm agents on their own
    // Pool with serial kernels (op-threads defaults to 1 under --exec
    // threads); cluster-gcn on an 8-thread op pool, serial batch prep.
    let admm_dual8 = time_admm_rt(Arc::new(NativeBackend::new()))?;
    let cg_dual8 = time_cg_rt(Arc::new(NativeBackend::with_threads(rt_threads)))?;
    // Shared: one runtime under the same total budget for both trainers.
    let shared_rt = Arc::new(Runtime::new(rt_threads));
    let shared_be: Arc<dyn ComputeBackend> =
        Arc::new(NativeBackend::with_runtime(shared_rt, false));
    let admm_shared8 = time_admm_rt(shared_be.clone())?;
    let cg_shared8 = time_cg_rt(shared_be)?;
    let runtime_ok = admm_shared8 <= admm_dual8 * 1.10;
    println!(
        "shared admm {:>10}/epoch vs dual {:>10}/epoch ({:+.1}%)   \
         cluster-gcn shared {:>10} vs dual {:>10} ({:+.1}%)",
        fmt_secs(admm_shared8),
        fmt_secs(admm_dual8),
        (admm_shared8 / admm_dual8 - 1.0) * 100.0,
        fmt_secs(cg_shared8),
        fmt_secs(cg_dual8),
        (cg_shared8 / cg_dual8 - 1.0) * 100.0
    );

    // ---- telemetry overhead gate (CGCN_BENCH_OBS_GATE=1) ------------------
    // Telemetry is contractually off the hot path (DESIGN.md §10): spans
    // and sharded counters at phase/chunk granularity, nothing in kernel
    // inner loops. This A/B flips the CGCN_OBS gate in-process around
    // otherwise-identical pooled ADMM runs and fails if enabling it costs
    // more than 5% per epoch.
    let obs_gate = env_flag("CGCN_BENCH_OBS_GATE");
    let mut obs_on_s = f64::NAN;
    let mut obs_off_s = f64::NAN;
    if obs_gate {
        section("telemetry overhead (CGCN_OBS on vs off, pooled admm epochs)");
        let obs_epochs = epochs.max(3);
        let time_admm = |on: bool| -> anyhow::Result<f64> {
            cgcn::obs::force(on);
            let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::with_threads(8));
            let mut hp_m = hp.clone();
            hp_m.communities = 3;
            let ws = Arc::new(Workspace::build(&ds, &hp_m, Method::Metis)?);
            let mut trainer = AdmmTrainer::new(ws, backend, AdmmOptions::for_mode(3))?;
            trainer.train(1, "obs-warmup")?;
            let t0 = Instant::now();
            trainer.train(obs_epochs, if on { "obs-on" } else { "obs-off" })?;
            Ok(t0.elapsed().as_secs_f64() / obs_epochs as f64)
        };
        obs_off_s = time_admm(false)?;
        obs_on_s = time_admm(true)?;
        cgcn::obs::force(true);
        println!(
            "obs off {:>10}/epoch   on {:>10}/epoch   overhead {:+.1}%",
            fmt_secs(obs_off_s),
            fmt_secs(obs_on_s),
            (obs_on_s / obs_off_s - 1.0) * 100.0
        );
    }

    // ---- gate + JSON ------------------------------------------------------
    let ref_ok = ref_pool_p50 <= ref_spawn_p50 * 1.10;
    let obs_ok = !obs_gate || obs_on_s <= obs_off_s * 1.05;
    let out = Json::obj(vec![
        ("bench", Json::str("kernel_bench")),
        ("host_threads", Json::num(host_threads as f64)),
        ("quick", Json::num(if quick { 1.0 } else { 0.0 })),
        ("spmm_nnz", Json::num(a.nnz() as f64)),
        ("kernels", Json::arr(cells.iter().map(Cell::json).collect())),
        ("epochs", Json::arr(epoch_rows)),
        (
            "runtime_ab",
            Json::obj(vec![
                ("threads", Json::num(rt_threads as f64)),
                ("admm_shared_epoch_s", Json::num(admm_shared8)),
                ("admm_dual_epoch_s", Json::num(admm_dual8)),
                ("admm_shared_speedup", Json::num(admm_dual8 / admm_shared8)),
                ("cluster_gcn_shared_epoch_s", Json::num(cg_shared8)),
                ("cluster_gcn_dual_epoch_s", Json::num(cg_dual8)),
                (
                    "cluster_gcn_shared_speedup",
                    Json::num(cg_dual8 / cg_shared8),
                ),
                (
                    "shared_not_slower",
                    Json::num(if runtime_ok { 1.0 } else { 0.0 }),
                ),
            ]),
        ),
        (
            "simd_ab",
            Json::obj(vec![
                ("avx_detected", Json::num(if simd_detected { 1.0 } else { 0.0 })),
                ("ops", Json::arr(simd_rows)),
                ("simd_not_slower", Json::num(if simd_ok { 1.0 } else { 0.0 })),
            ]),
        ),
        (
            "gate",
            Json::obj(vec![
                ("ref_op", Json::str("hidden_residual")),
                ("ref_threads", Json::num(8.0)),
                ("pool_p50_s", Json::num(ref_pool_p50)),
                ("spawn_p50_s", Json::num(ref_spawn_p50)),
                ("pool_not_slower", Json::num(if ref_ok { 1.0 } else { 0.0 })),
                ("admm_pool_epoch_s", Json::num(admm_pool8)),
                ("admm_spawn_epoch_s", Json::num(admm_spawn8)),
                (
                    "admm_pool_speedup",
                    Json::num(admm_spawn8 / admm_pool8),
                ),
                // NaN is not JSON: report 0 when the obs A/B did not run.
                (
                    "obs_off_epoch_s",
                    Json::num(if obs_gate { obs_off_s } else { 0.0 }),
                ),
                (
                    "obs_on_epoch_s",
                    Json::num(if obs_gate { obs_on_s } else { 0.0 }),
                ),
                (
                    "obs_overhead_ok",
                    Json::num(if obs_ok { 1.0 } else { 0.0 }),
                ),
            ]),
        ),
    ]);
    std::fs::write("BENCH_kernels.json", out.to_pretty() + "\n")?;
    println!(
        "\n(wrote BENCH_kernels.json; pool {:>10} vs spawn {:>10} on hidden_residual@8t, \
         admm epoch pool {:>10} vs spawn {:>10})",
        fmt_secs(ref_pool_p50),
        fmt_secs(ref_spawn_p50),
        fmt_secs(admm_pool8),
        fmt_secs(admm_spawn8)
    );
    if rt_gate && !runtime_ok {
        anyhow::bail!(
            "gate: shared runtime slower than dual pools on the 8-thread \
             end-to-end ADMM epoch (shared {:.3e}s vs dual {:.3e}s)",
            admm_shared8,
            admm_dual8
        );
    }
    if simd_gate && simd_detected && !simd_ok {
        anyhow::bail!(
            "gate: simd microkernel slower than the scalar inner loop on a \
             large dense shape (see simd_ab in BENCH_kernels.json)"
        );
    }
    if gate && !ref_ok {
        anyhow::bail!(
            "gate: pooled executor slower than spawn-per-op at 8 threads \
             (pool {:.3e}s vs spawn {:.3e}s on hidden_residual {n}x256)",
            ref_pool_p50,
            ref_spawn_p50
        );
    }
    if !obs_ok {
        anyhow::bail!(
            "gate: telemetry overhead {:.1}% exceeds 5% \
             (admm epoch on {:.3e}s vs off {:.3e}s)",
            (obs_on_s / obs_off_s - 1.0) * 100.0,
            obs_on_s,
            obs_off_s
        );
    }
    Ok(())
}
