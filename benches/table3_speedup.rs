//! Table 3 reproduction: training/communication time and speedup of
//! Serial vs Parallel ADMM on both (synthetic) Amazon datasets.
//!
//! Prints the same six columns as the paper. Absolute numbers differ (our
//! substrate is a 1-core container with a virtual-time link model — see
//! DESIGN.md §2); the claims under test are the *shape*: parallel ≳ 2×
//! faster end-to-end, training time cut by a large factor, communication
//! visible but not dominant.
//!
//! Env knobs: CGCN_BENCH_EPOCHS (default 50), CGCN_BENCH_SCALE (default
//! 0.25), CGCN_ARTIFACTS.

use cgcn::config::HyperParams;
use cgcn::coordinator::{AdmmOptions, AdmmTrainer, Workspace};
use cgcn::data::synth;
use cgcn::metrics::RunReport;
use cgcn::partition::Method;
use cgcn::runtime::{default_backend, ComputeBackend};
use std::sync::Arc;

fn env_or<T: std::str::FromStr>(key: &str, default: T) -> T {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    cgcn::util::logger::init();
    let epochs: usize = env_or("CGCN_BENCH_EPOCHS", 50);
    let scale: f64 = env_or("CGCN_BENCH_SCALE", 0.25);
    let backend = default_backend();
    eprintln!("table3_speedup: backend = {}", backend.name());

    println!("Table 3 — Serial vs Parallel ADMM ({epochs} epochs, scale {scale}, virtual time)");
    println!(
        "{:<22} {:>9} {:>10} {:>14} {:>9}   {:>10} {:>10}",
        "", "Total(s)", "Train(s)", "Comm(s)", "Speedup", "train acc", "test acc"
    );

    for spec in [synth::AMAZON_COMPUTERS, synth::AMAZON_PHOTO] {
        let ds = synth::generate(&spec, scale, 17);
        let hp = HyperParams::for_dataset(spec.name);
        let run = |m: usize| -> anyhow::Result<RunReport> {
            let mut hp_m = hp.clone();
            hp_m.communities = m;
            let ws = Arc::new(Workspace::build(&ds, &hp_m, Method::Metis)?);
            let mut t = AdmmTrainer::new(ws, backend.clone(), AdmmOptions::for_mode(m))?;
            t.train(epochs, if m == 1 { "serial" } else { "parallel" })
        };
        let serial = run(1)?;
        let parallel = run(3)?;
        println!("--- {}", ds.name);
        println!(
            "{}   {:>10.3} {:>10.3}",
            serial.table3_row("Serial ADMM", None),
            serial.final_train_acc(),
            serial.final_test_acc()
        );
        println!(
            "{}   {:>10.3} {:>10.3}",
            parallel.table3_row(
                "Parallel ADMM (M=3)",
                Some(serial.total_virtual() / parallel.total_virtual())
            ),
            parallel.final_train_acc(),
            parallel.final_test_acc()
        );
        println!(
            "    training-time reduction {:.1}%   comm {:.2} MB/epoch   wall {:.1}s vs {:.1}s",
            100.0 * (1.0 - parallel.total_train() / serial.total_train()),
            parallel.total_bytes() as f64 / parallel.epochs.len() as f64 / 1e6,
            serial.total_wall(),
            parallel.total_wall()
        );
    }
    println!(
        "\npaper (their testbed): computers 80.82s -> 24.48s (3.30x), photo 50.81s -> 17.07s (2.98x)"
    );
    Ok(())
}
