//! Figure 2 reproduction: training/test accuracy per epoch for all six
//! methods (Serial ADMM, Parallel ADMM, Adam, Adagrad, GD, Adadelta) on
//! both synthetic datasets.
//!
//! Writes the full per-epoch series to results/fig2_<dataset>.csv and
//! prints accuracy checkpoints. Claims under test (paper §4.2): both ADMM
//! variants converge among the fastest and land near Adam by epoch 50,
//! clearly above GD/Adagrad/Adadelta at the paper's learning rates; Serial
//! ADMM tracks at or above Parallel ADMM.
//!
//! Env knobs: CGCN_BENCH_EPOCHS (default 50), CGCN_BENCH_SCALE (0.25).

use cgcn::baselines::{BaselineTrainer, Optimizer};
use cgcn::config::HyperParams;
use cgcn::coordinator::{AdmmOptions, AdmmTrainer, Workspace};
use cgcn::data::synth;
use cgcn::metrics::RunReport;
use cgcn::partition::Method;
use cgcn::runtime::{default_backend, ComputeBackend};
use std::sync::Arc;

fn env_or<T: std::str::FromStr>(key: &str, default: T) -> T {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    cgcn::util::logger::init();
    let epochs: usize = env_or("CGCN_BENCH_EPOCHS", 50);
    let scale: f64 = env_or("CGCN_BENCH_SCALE", 0.25);
    let backend = default_backend();
    eprintln!("fig2_accuracy: backend = {}", backend.name());
    std::fs::create_dir_all("results")?;

    for spec in [synth::AMAZON_COMPUTERS, synth::AMAZON_PHOTO] {
        let ds = synth::generate(&spec, scale, 17);
        let hp = HyperParams::for_dataset(spec.name);
        let mut reports: Vec<RunReport> = Vec::new();

        for m in [1usize, 3] {
            let mut hp_m = hp.clone();
            hp_m.communities = m;
            let ws = Arc::new(Workspace::build(&ds, &hp_m, Method::Metis)?);
            let mut t = AdmmTrainer::new(ws, backend.clone(), AdmmOptions::for_mode(m))?;
            let label = if m == 1 { "admm-serial" } else { "admm-parallel" };
            log::info!("[{}] {label}", ds.name);
            let mut rep = t.train(epochs, label)?;
            rep.dataset = ds.name.clone();
            reports.push(rep);
        }
        let mut hp_b = hp.clone();
        hp_b.communities = 1;
        let ws = Arc::new(Workspace::build(&ds, &hp_b, Method::Metis)?);
        for name in ["adam", "adagrad", "gd", "adadelta"] {
            log::info!("[{}] {name}", ds.name);
            let opt = Optimizer::parse(name, None)?;
            let mut t = BaselineTrainer::new(ws.clone(), backend.clone(), opt)?;
            let mut rep = t.train(epochs)?;
            rep.dataset = ds.name.clone();
            reports.push(rep);
        }

        // CSV (all series, one file per dataset).
        let path = format!("results/fig2_{}.csv", spec.name);
        let mut csv = String::new();
        for (i, rep) in reports.iter().enumerate() {
            let body = rep.to_csv();
            csv.push_str(if i == 0 {
                &body
            } else {
                body.split_once('\n').unwrap().1
            });
        }
        std::fs::write(&path, &csv)?;

        // Checkpoint table (paper reads accuracies off the curves).
        println!("\nFigure 2 — {} (test accuracy @ epoch; csv: {path})", ds.name);
        let marks: Vec<usize> = [9, 19, 29, 39, epochs - 1]
            .iter()
            .copied()
            .filter(|&e| e < epochs)
            .collect();
        print!("{:<16}", "method");
        for e in &marks {
            print!(" {:>8}", format!("ep{}", e + 1));
        }
        println!(" {:>8} {:>10}", "best", "final trn");
        for rep in &reports {
            print!("{:<16}", rep.method);
            for &e in &marks {
                print!(" {:>8.3}", rep.epochs[e].test_acc);
            }
            println!(
                " {:>8.3} {:>10.3}",
                rep.best_test_acc(),
                rep.final_train_acc()
            );
        }
    }
    Ok(())
}
