#!/usr/bin/env bash
# CI gate for the default (no-xla) feature set. Everything here must run
# offline: the only dependencies are the in-tree shims under rust/shims/.
#
#   ./ci.sh          # fmt + clippy + tests
#   ./ci.sh fast     # tests only
set -euo pipefail
cd "$(dirname "$0")"

if [[ "${1:-}" != "fast" ]]; then
    echo "==> cargo fmt --check"
    cargo fmt --check

    echo "==> cargo clippy (deny warnings)"
    cargo clippy --all-targets -- -D warnings
fi

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "CI OK"
