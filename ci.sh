#!/usr/bin/env bash
# CI gate for the default (no-xla) feature set. Everything here must run
# offline: the only dependencies are the in-tree shims under rust/shims/.
#
#   ./ci.sh          # fmt + clippy + tests
#   ./ci.sh fast     # tests only
set -euo pipefail
cd "$(dirname "$0")"

if [[ "${1:-}" != "fast" ]]; then
    echo "==> cargo fmt --check"
    cargo fmt --check

    echo "==> cargo clippy (deny warnings)"
    cargo clippy --all-targets -- -D warnings
fi

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "==> CGCN_SIMD=off smoke (scalar fallback must stay bitwise identical)"
CGCN_SIMD=off cargo test -q --test backend_parallel

SMOKE_DIR="$(mktemp -d)"
cleanup() {
    [[ -n "${SERVE_PID:-}" ]] && kill "$SERVE_PID" 2>/dev/null || true
    rm -rf "$SMOKE_DIR"
}
trap cleanup EXIT

# Start the server on an ephemeral port; sets SERVE_PID and ADDR.
serve_start() { # <model> <addr-file>
    target/release/cgcn serve --model "$1" --addr 127.0.0.1:0 \
        --addr-file "$2" --threads 2 --batch-window-us 200 &
    SERVE_PID=$!
    for _ in $(seq 1 100); do
        [[ -s "$2" ]] && break
        sleep 0.1
    done
    [[ -s "$2" ]] || { echo "serve did not come up"; exit 1; }
    ADDR="$(cat "$2")"
}

# Remote shutdown + bounded wait: a shutdown regression must fail CI,
# not hang it.
serve_stop() {
    target/release/cgcn query --addr "$ADDR" --shutdown-server
    for _ in $(seq 1 60); do
        kill -0 "$SERVE_PID" 2>/dev/null || break
        sleep 0.5
    done
    if kill -0 "$SERVE_PID" 2>/dev/null; then
        echo "server failed to exit within 30s of shutdown"
        exit 1
    fi
    wait "$SERVE_PID"
    SERVE_PID=""
}

echo "==> serve smoke test (train --save → serve → query --verify)"
MODEL="$SMOKE_DIR/model.cgnm"
target/release/cgcn train --dataset caveman --communities 3 --epochs 3 \
    --save "$MODEL" >/dev/null
serve_start "$MODEL" "$SMOKE_DIR/addr"
# Served logits must be bitwise-identical to the in-process forward pass.
target/release/cgcn query --addr "$ADDR" --model "$MODEL" --verify
target/release/cgcn query --addr "$ADDR" --nodes 0,1,2 >/dev/null
target/release/cgcn loadgen --addr "$ADDR" --clients 2 --requests 20 >/dev/null
serve_stop

echo "==> cluster-gcn smoke test (mini-batch train --save → serve → query --verify)"
MB_MODEL="$SMOKE_DIR/minibatch.cgnm"
target/release/cgcn train --dataset caveman --method cluster-gcn \
    --clusters 8 --batch-clusters 2 --epochs 3 --save "$MB_MODEL" >/dev/null
serve_start "$MB_MODEL" "$SMOKE_DIR/mb_addr"
# A mini-batch-trained snapshot must serve bitwise-identically too.
target/release/cgcn query --addr "$ADDR" --model "$MB_MODEL" --verify
serve_stop

echo "==> fault-tolerance smoke (kill -9 a tcp worker mid-run; leader recovers)"
FT_DIR="$SMOKE_DIR/ft_ckpt"
FT_LOG="$SMOKE_DIR/ft_leader.log"
target/release/cgcn train --dataset synth-computers --scale 0.1 --hidden 64 \
    --communities 3 --epochs 30 --transport tcp \
    --checkpoint-every 5 --checkpoint-dir "$FT_DIR" \
    > "$SMOKE_DIR/ft_run.json" 2> "$FT_LOG" &
LEADER_PID=$!
# Gate the kill on observed progress, not a fixed sleep: once the leader
# has logged a completed epoch, all workers are connected and ~28 epochs
# remain, so the kill is guaranteed to land mid-run.
for _ in $(seq 1 1200); do
    grep -q "epoch 1:" "$FT_LOG" 2>/dev/null && break
    sleep 0.05
done
grep -q "epoch 1:" "$FT_LOG" || { echo "tcp run never reached epoch 1"; cat "$FT_LOG"; exit 1; }
WPID="$(pgrep -f 'cgcn worker --listen' | head -1 || true)"
[[ -n "$WPID" ]] || { echo "no tcp worker process found"; exit 1; }
kill -9 "$WPID"
# The leader must detect the dead agent, reassign its communities and
# finish the full run with exit 0.
wait "$LEADER_PID"
grep -q "reassigning its communities" "$FT_LOG" \
    || { echo "leader never logged a recovery"; cat "$FT_LOG"; exit 1; }
grep -q '"final_test_acc"' "$SMOKE_DIR/ft_run.json"

echo "==> fault-tolerance smoke (leader crash after checkpoint; --resume completes)"
FT2_DIR="$SMOKE_DIR/ft2_ckpt"
set +e
CGCN_TEST_LEADER_CRASH_AT=4 target/release/cgcn train --dataset caveman \
    --communities 3 --epochs 8 --transport tcp \
    --checkpoint-every 2 --checkpoint-dir "$FT2_DIR" >/dev/null 2>&1
CRASH_RC=$?
set -e
[[ "$CRASH_RC" -ne 0 ]] || { echo "leader was expected to crash"; exit 1; }
LAST_CKPT="$FT2_DIR/$(ls "$FT2_DIR" | sort | tail -1)"
target/release/cgcn train --resume "$LAST_CKPT" --epochs 8 --transport tcp \
    --save "$SMOKE_DIR/resumed.cgnm" >/dev/null
# Resume determinism: the recovered pipeline's snapshot is byte-identical
# to an uninterrupted run's.
target/release/cgcn train --dataset caveman --communities 3 --epochs 8 \
    --transport tcp --save "$SMOKE_DIR/uninterrupted.cgnm" >/dev/null
cmp "$SMOKE_DIR/resumed.cgnm" "$SMOKE_DIR/uninterrupted.cgnm"

echo "==> observability smoke (train --trace-out/--metrics-out, serve + stats)"
TRACE="$SMOKE_DIR/trace.json"
METRICS="$SMOKE_DIR/metrics.json"
target/release/cgcn train --dataset caveman --communities 3 --epochs 3 \
    --trace-out "$TRACE" --metrics-out "$METRICS" >/dev/null
# The Chrome trace must carry the ADMM phase spans (per-community lanes).
grep -q '"admm.w_update"' "$TRACE" || { echo "trace has no admm.w_update spans"; exit 1; }
grep -q '"admm.z_update"' "$TRACE" || { echo "trace has no admm.z_update spans"; exit 1; }
grep -q '"traceEvents"' "$TRACE"
# The metrics dump must have counted the epochs we ran.
grep -q '"admm.epochs": 3' "$METRICS" || { echo "metrics.json missed admm.epochs"; cat "$METRICS"; exit 1; }
grep -q '"spans"' "$METRICS"
# CGCN_OBS=off must still train and must leave the outputs empty of spans.
CGCN_OBS=off target/release/cgcn train --dataset caveman --communities 3 --epochs 2 \
    --trace-out "$SMOKE_DIR/trace_off.json" >/dev/null
grep -q '"admm.w_update"' "$SMOKE_DIR/trace_off.json" \
    && { echo "CGCN_OBS=off still recorded spans"; exit 1; }
# Live scrape: the stats subcommand reports non-zero serve counters and
# request-latency quantiles from the server process's registry.
serve_start "$MODEL" "$SMOKE_DIR/obs_addr"
target/release/cgcn query --addr "$ADDR" --nodes 0,1,2 >/dev/null
STATS_OUT="$(target/release/cgcn stats --addr "$ADDR")"
echo "$STATS_OUT" | grep -q 'requests 1' || { echo "stats missed the query"; echo "$STATS_OUT"; exit 1; }
echo "$STATS_OUT" | grep -q 'cgcn_serve_connections_total' \
    || { echo "stats carried no registry text"; echo "$STATS_OUT"; exit 1; }
echo "$STATS_OUT" | grep -q 'cgcn_serve_request_secs{quantile="0.99"}' \
    || { echo "stats carried no latency quantiles"; echo "$STATS_OUT"; exit 1; }
serve_stop

echo "==> community partition smoke (cgcn partition → train --partition-file roundtrip)"
PART_FILE="$SMOKE_DIR/louvain_part.json"
PART_REPORT="$SMOKE_DIR/partition_quality.json"
target/release/cgcn partition --dataset caveman --communities 3 \
    --partition louvain --partition-file "$PART_FILE" --out "$PART_REPORT"
grep -q '"cgcn-partition-v1"' "$PART_FILE" || { echo "partition export missing format tag"; exit 1; }
grep -q '"modularity"' "$PART_REPORT" || { echo "quality report missing modularity"; exit 1; }
# Louvain end-to-end on the ADMM path, bitwise-deterministic across
# thread counts (the detector parallelises on the shared runtime).
target/release/cgcn train --dataset caveman --communities 3 --epochs 3 \
    --partition louvain --op-threads 1 --save "$SMOKE_DIR/louvain_t1.cgnm" >/dev/null
target/release/cgcn train --dataset caveman --communities 3 --epochs 3 \
    --partition louvain --op-threads 8 --save "$SMOKE_DIR/louvain_t8.cgnm" >/dev/null
cmp "$SMOKE_DIR/louvain_t1.cgnm" "$SMOKE_DIR/louvain_t8.cgnm"
# Importing the exported assignment must reproduce the same model.
target/release/cgcn train --dataset caveman --communities 3 --epochs 3 \
    --partition-file "$PART_FILE" --save "$SMOKE_DIR/louvain_file.cgnm" >/dev/null
cmp "$SMOKE_DIR/louvain_t1.cgnm" "$SMOKE_DIR/louvain_file.cgnm"
# The cluster-gcn mini-batch path must accept community partitions too.
target/release/cgcn train --dataset caveman --method cluster-gcn \
    --partition louvain --clusters 8 --batch-clusters 2 --epochs 2 >/dev/null

echo "==> quickstart example (release)"
cargo run --release --example quickstart >/dev/null

echo "==> kernel bench quick gate (pool vs spawn; shared vs dual runtime; simd vs scalar; telemetry overhead <=5%)"
# Writes BENCH_kernels.json; CGCN_BENCH_GATE makes the bench exit non-zero
# if the persistent pool is slower (>10% noise margin) than the legacy
# spawn-per-op executor at 8 threads on the reference elementwise shape,
# CGCN_BENCH_RUNTIME_GATE if the shared work-stealing runtime loses to the
# legacy dual pools on the 8-thread end-to-end ADMM epoch (same margin),
# CGCN_BENCH_SIMD_GATE if the 8-wide AVX microkernel loses to the scalar
# inner loop on any large dense matmul shape (skipped when AVX is absent),
# and CGCN_BENCH_OBS_GATE if enabling CGCN_OBS costs >5% per ADMM epoch.
CGCN_BENCH_QUICK=1 CGCN_BENCH_GATE=1 CGCN_BENCH_RUNTIME_GATE=1 \
    CGCN_BENCH_SIMD_GATE=1 CGCN_BENCH_OBS_GATE=1 cargo bench --bench kernel_bench
[[ -s BENCH_kernels.json ]] || { echo "kernel bench wrote no BENCH_kernels.json"; exit 1; }

echo "==> partition bench quick gate (louvain modularity vs random; edge-cut vs metis)"
# Writes BENCH_partition.json; CGCN_BENCH_PARTITION_GATE makes the bench
# exit non-zero unless louvain beats random modularity by >=0.15 and keeps
# its edge-cut within 2x of metis on every synth graph.
CGCN_BENCH_QUICK=1 CGCN_BENCH_PARTITION_GATE=1 cargo bench --bench partition_bench
[[ -s BENCH_partition.json ]] || { echo "partition bench wrote no BENCH_partition.json"; exit 1; }

echo "CI OK"
