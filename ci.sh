#!/usr/bin/env bash
# CI gate for the default (no-xla) feature set. Everything here must run
# offline: the only dependencies are the in-tree shims under rust/shims/.
#
#   ./ci.sh          # fmt + clippy + tests
#   ./ci.sh fast     # tests only
set -euo pipefail
cd "$(dirname "$0")"

if [[ "${1:-}" != "fast" ]]; then
    echo "==> cargo fmt --check"
    cargo fmt --check

    echo "==> cargo clippy (deny warnings)"
    cargo clippy --all-targets -- -D warnings
fi

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

SMOKE_DIR="$(mktemp -d)"
cleanup() {
    [[ -n "${SERVE_PID:-}" ]] && kill "$SERVE_PID" 2>/dev/null || true
    rm -rf "$SMOKE_DIR"
}
trap cleanup EXIT

# Start the server on an ephemeral port; sets SERVE_PID and ADDR.
serve_start() { # <model> <addr-file>
    target/release/cgcn serve --model "$1" --addr 127.0.0.1:0 \
        --addr-file "$2" --threads 2 --batch-window-us 200 &
    SERVE_PID=$!
    for _ in $(seq 1 100); do
        [[ -s "$2" ]] && break
        sleep 0.1
    done
    [[ -s "$2" ]] || { echo "serve did not come up"; exit 1; }
    ADDR="$(cat "$2")"
}

# Remote shutdown + bounded wait: a shutdown regression must fail CI,
# not hang it.
serve_stop() {
    target/release/cgcn query --addr "$ADDR" --shutdown-server
    for _ in $(seq 1 60); do
        kill -0 "$SERVE_PID" 2>/dev/null || break
        sleep 0.5
    done
    if kill -0 "$SERVE_PID" 2>/dev/null; then
        echo "server failed to exit within 30s of shutdown"
        exit 1
    fi
    wait "$SERVE_PID"
    SERVE_PID=""
}

echo "==> serve smoke test (train --save → serve → query --verify)"
MODEL="$SMOKE_DIR/model.cgnm"
target/release/cgcn train --dataset caveman --communities 3 --epochs 3 \
    --save "$MODEL" >/dev/null
serve_start "$MODEL" "$SMOKE_DIR/addr"
# Served logits must be bitwise-identical to the in-process forward pass.
target/release/cgcn query --addr "$ADDR" --model "$MODEL" --verify
target/release/cgcn query --addr "$ADDR" --nodes 0,1,2 >/dev/null
target/release/cgcn loadgen --addr "$ADDR" --clients 2 --requests 20 >/dev/null
serve_stop

echo "==> cluster-gcn smoke test (mini-batch train --save → serve → query --verify)"
MB_MODEL="$SMOKE_DIR/minibatch.cgnm"
target/release/cgcn train --dataset caveman --method cluster-gcn \
    --clusters 8 --batch-clusters 2 --epochs 3 --save "$MB_MODEL" >/dev/null
serve_start "$MB_MODEL" "$SMOKE_DIR/mb_addr"
# A mini-batch-trained snapshot must serve bitwise-identically too.
target/release/cgcn query --addr "$ADDR" --model "$MB_MODEL" --verify
serve_stop

echo "==> quickstart example (release)"
cargo run --release --example quickstart >/dev/null

echo "CI OK"
