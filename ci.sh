#!/usr/bin/env bash
# CI gate for the default (no-xla) feature set. Everything here must run
# offline: the only dependencies are the in-tree shims under rust/shims/.
#
#   ./ci.sh          # fmt + clippy + tests
#   ./ci.sh fast     # tests only
set -euo pipefail
cd "$(dirname "$0")"

if [[ "${1:-}" != "fast" ]]; then
    echo "==> cargo fmt --check"
    cargo fmt --check

    echo "==> cargo clippy (deny warnings)"
    cargo clippy --all-targets -- -D warnings
fi

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "==> serve smoke test (train --save → serve → query --verify)"
SMOKE_DIR="$(mktemp -d)"
MODEL="$SMOKE_DIR/model.cgnm"
ADDR_FILE="$SMOKE_DIR/addr"
cleanup() {
    [[ -n "${SERVE_PID:-}" ]] && kill "$SERVE_PID" 2>/dev/null || true
    rm -rf "$SMOKE_DIR"
}
trap cleanup EXIT

target/release/cgcn train --dataset caveman --communities 3 --epochs 3 \
    --save "$MODEL" >/dev/null
target/release/cgcn serve --model "$MODEL" --addr 127.0.0.1:0 \
    --addr-file "$ADDR_FILE" --threads 2 --batch-window-us 200 &
SERVE_PID=$!
for _ in $(seq 1 100); do
    [[ -s "$ADDR_FILE" ]] && break
    sleep 0.1
done
[[ -s "$ADDR_FILE" ]] || { echo "serve did not come up"; exit 1; }
ADDR="$(cat "$ADDR_FILE")"
# Served logits must be bitwise-identical to the in-process forward pass.
target/release/cgcn query --addr "$ADDR" --model "$MODEL" --verify
target/release/cgcn query --addr "$ADDR" --nodes 0,1,2 >/dev/null
target/release/cgcn loadgen --addr "$ADDR" --clients 2 --requests 20 >/dev/null
target/release/cgcn query --addr "$ADDR" --shutdown-server
# Bounded wait: a shutdown regression must fail CI, not hang it.
for _ in $(seq 1 60); do
    kill -0 "$SERVE_PID" 2>/dev/null || break
    sleep 0.5
done
if kill -0 "$SERVE_PID" 2>/dev/null; then
    echo "server failed to exit within 30s of shutdown"
    exit 1
fi
wait "$SERVE_PID"
SERVE_PID=""

echo "==> quickstart example (release)"
cargo run --release --example quickstart >/dev/null

echo "CI OK"
