"""L2 — the JAX compute graphs for every ADMM subproblem and baseline step.

Each public ``build_*`` function returns ``(fn, example_args)`` where ``fn``
is a pure jax function over fixed shapes. ``aot.py`` lowers each to HLO
text; the Rust coordinator executes them via PJRT with Python long gone.

Decomposition (see DESIGN.md §1): the coordinator interleaves CSR SpMM
(`Ã ·`, Rust) with these dense graphs, and every update is arranged so the
SpMM runs over the *post-projection* width:

    V   = Z_{l-1} W_l              (mm_nn — dense, Pallas-tiled)
    pre = Ã V + c                  (SpMM + elementwise add, Rust)
    (val, R) = *_residual(pre, …)  (elementwise artifact)
    grad_W   = Z_{l-1}ᵀ (Ã R)      (SpMM, then mm_tn)
    grad_Z  += (Ã R) Wᵀ            (SpMM, then mm_bt)

because `Ã (Z W)` touches `C_l ≤ hidden` columns instead of the raw
feature width (767/745) — the same associativity trick the paper's message
definition `p = Ã Z W` exploits.

All scalars (ν, ρ, θ, denom) are rank-0 f32 *inputs* so one artifact serves
every hyper-parameter setting. f = ReLU with f'(0) := 0 throughout (this is
what keeps zero-padded community rows provably inert).
"""

import jax
import jax.numpy as jnp

from .kernels import matmul, softmax_xent
from .kernels.ref import relu_grad_mask

F32 = jnp.float32


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def _scalar():
    return jax.ShapeDtypeStruct((), F32)


# --------------------------------------------------------------------------
# Matmul primitives (all Pallas-tiled)
# --------------------------------------------------------------------------


def build_mm_nn(n, a, b, use_pallas=True):
    """X @ W — projections V = Z W, logits, Q assembly."""

    def fn(x, w):
        return (matmul(x, w, use_pallas=use_pallas),)

    return fn, (_spec(n, a), _spec(a, b))


def build_mm_tn(n, a, b, use_pallas=True):
    """Xᵀ @ Y — weight gradients gW = Z_{l-1}ᵀ (Ã R)."""

    def fn(x, y):
        return (matmul(x.T, y, use_pallas=use_pallas),)

    return fn, (_spec(n, a), _spec(n, b))


def build_mm_bt(n, a, b, use_pallas=True):
    """X @ Wᵀ — Z-gradient back-projection (Ã R) Wᵀ."""

    def fn(x, w):
        return (matmul(x, w.T, use_pallas=use_pallas),)

    return fn, (_spec(n, b), _spec(a, b))


def build_fwd_relu(n, a, b, use_pallas=True):
    """ReLU(H @ W) — forward hidden layer (eval, init, baselines)."""

    def fn(h, w):
        return (matmul(h, w, relu=True, use_pallas=use_pallas),)

    return fn, (_spec(n, a), _spec(a, b))


# --------------------------------------------------------------------------
# Elementwise residuals shared by the W (§3.1) and Z (Appendix A)
# subproblems. `pre` is the aggregated pre-activation Ã(ZW)+c from Rust.
# --------------------------------------------------------------------------


def build_hidden_residual(n, c):
    """ν-coupling term at a ReLU layer:

    val = ν/2 ||f(pre) − Zt||²,  R = ν (f(pre) − Zt) ⊙ f'(pre).

    Used as-is for ∂φ/∂W_l (l<L) and for the eq.-5 ψ pieces.
    """

    def fn(pre, zt, nu):
        act = jnp.maximum(pre, 0.0)
        d = act - zt
        val = 0.5 * nu * jnp.sum(d * d)
        r = nu * d * relu_grad_mask(pre)
        return val, r

    return fn, (_spec(n, c), _spec(n, c), _scalar())


def build_out_residual(n, c):
    """Augmented-Lagrangian term at the linear output layer:

    val = <U, Zt − pre> + ρ/2 ||Zt − pre||²,  R = −(U + ρ(Zt − pre)).

    (R is the gradient of val wrt `pre`; shared by ∂φ/∂W_L and the eq.-6
    ψ pieces.)
    """

    def fn(pre, zt, u, rho):
        d = zt - pre
        val = jnp.sum(u * d) + 0.5 * rho * jnp.sum(d * d)
        r = -(u + rho * d)
        return val, r

    return fn, (_spec(n, c), _spec(n, c), _spec(n, c), _scalar())


def build_hidden_phi(n, c):
    """Value-only hidden coupling (τ/θ backtracking)."""

    def fn(pre, zt, nu):
        d = jnp.maximum(pre, 0.0) - zt
        return (0.5 * nu * jnp.sum(d * d),)

    return fn, (_spec(n, c), _spec(n, c), _scalar())


def build_out_phi(n, c):
    """Value-only output coupling (τ/θ backtracking)."""

    def fn(pre, zt, u, rho):
        d = zt - pre
        return (jnp.sum(u * d) + 0.5 * rho * jnp.sum(d * d),)

    return fn, (_spec(n, c), _spec(n, c), _spec(n, c), _scalar())


# --------------------------------------------------------------------------
# Z-subproblem step (eq. 8/10)
# --------------------------------------------------------------------------


def build_z_combine(n, c):
    """Proximal gradient + quadratic-approximation step:

    g = ν(Z − f(Pin)) + Gsum;   Z⁺ = Z − g/θ.
    Returns (Z⁺, prox value ν/2||Z−f(Pin)||², ||g||²) — the gradient norm
    feeds the backtracking test ψ(Z⁺) ≤ ψ(Z) − ||g||²/(2θ).
    """

    def fn(z, pin, gsum, nu, theta):
        fpin = jnp.maximum(pin, 0.0)
        d = z - fpin
        val = 0.5 * nu * jnp.sum(d * d)
        g = nu * d + gsum
        znew = z - g / theta
        return znew, val, jnp.sum(g * g)

    return fn, (_spec(n, c), _spec(n, c), _spec(n, c), _scalar(), _scalar())


def build_z_prox_val(n, c):
    """Value-only proximal term ν/2||Z − f(Pin)||² (θ backtracking)."""

    def fn(z, pin, nu):
        d = z - jnp.maximum(pin, 0.0)
        return (0.5 * nu * jnp.sum(d * d),)

    return fn, (_spec(n, c), _spec(n, c), _scalar())


# --------------------------------------------------------------------------
# Z_L subproblem — FISTA on the risk (eq. 7)
# --------------------------------------------------------------------------


def build_zl_fista(n, c, steps=10, use_pallas=True):
    """argmin_Z R(Z, Y) + <U, Z − Q> + ρ/2||Z − Q||² via FISTA [Beck'09].

    R is the masked mean softmax cross-entropy (global denom — see
    kernels/softmax_xent.py). The objective gradient is
    ∇ = xent_grad(Z) + U + ρ(Z − Q); its Lipschitz constant is bounded by
    ρ + 1/2 (softmax Hessian ≤ 1/2, masks ≤ 1, denom ≥ 1), giving the
    static step 1/(ρ + 1/2). `steps` FISTA iterations are unrolled into the
    artifact. Returns (Z⁺, risk value at Z⁺).
    """

    def fn(q, u, y, mask, z0, rho, denom):
        step = 1.0 / (rho + 0.5)

        def grad_at(z):
            loss, g = softmax_xent(z, y, mask, denom, use_pallas=use_pallas)
            return loss, g + u + rho * (z - q)

        z = z0
        v = z0
        t = 1.0
        for _ in range(steps):
            _, g = grad_at(v)
            z_next = v - step * g
            t_next = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
            v = z_next + ((t - 1.0) / t_next) * (z_next - z)
            z, t = z_next, t_next
        loss, _ = softmax_xent(z, y, mask, denom, use_pallas=use_pallas)
        return z, loss

    return fn, (
        _spec(n, c),
        _spec(n, c),
        _spec(n, c),
        _spec(n),
        _spec(n, c),
        _scalar(),
        _scalar(),
    )


# --------------------------------------------------------------------------
# Backprop baselines (GD / Adam / Adagrad / Adadelta drive these)
# --------------------------------------------------------------------------


def build_bp_out_grads(n, a, b, use_pallas=True):
    """Loss head + gradients of the 2-layer GCN baseline.

    logits = H1 W2 (H1 = Ã Z1 from SpMM);
    returns (loss, dW2 = H1ᵀ dL, dH1 = dL W2ᵀ).
    """

    def fn(h1, w2, y, mask, denom):
        logits = matmul(h1, w2, use_pallas=use_pallas)
        loss, dl = softmax_xent(logits, y, mask, denom, use_pallas=use_pallas)
        dw2 = matmul(h1.T, dl, use_pallas=use_pallas)
        dh1 = matmul(dl, w2.T, use_pallas=use_pallas)
        return loss, dw2, dh1

    return fn, (_spec(n, a), _spec(a, b), _spec(n, b), _spec(n), _scalar())


def build_bp_hidden_grads(n, a, b, use_pallas=True):
    """dW1 = H0ᵀ (dZ1 ⊙ f'(H0 W1)) — the hidden-layer backward tail.

    dZ1 arrives from the coordinator's SpMM (dZ1 = Ã dH1, Ã symmetric).
    """

    def fn(h0, w1, dz1):
        pre = matmul(h0, w1, use_pallas=use_pallas)
        r = dz1 * relu_grad_mask(pre)
        dw1 = matmul(h0.T, r, use_pallas=use_pallas)
        return (dw1,)

    return fn, (_spec(n, a), _spec(a, b), _spec(n, b))


def build_xent_loss(n, c, use_pallas=True):
    """Standalone masked CE loss (epoch logging / eval)."""

    def fn(logits, y, mask, denom):
        loss, _ = softmax_xent(logits, y, mask, denom, use_pallas=use_pallas)
        return (loss,)

    return fn, (_spec(n, c), _spec(n, c), _spec(n), _scalar())


# --------------------------------------------------------------------------
# Entry registry — aot.py iterates this.
# --------------------------------------------------------------------------

# name -> (builder, shape-kind): "nab" = (n, a, b, use_pallas),
# "nc" = (n, c, use_pallas), "nc_steps" = (n, c, steps, use_pallas).
ENTRIES = {
    "mm_nn": (build_mm_nn, "nab"),
    "mm_tn": (build_mm_tn, "nab"),
    "mm_bt": (build_mm_bt, "nab"),
    "fwd_relu": (build_fwd_relu, "nab"),
    "hidden_residual": (lambda n, c, up: build_hidden_residual(n, c), "nc"),
    "out_residual": (lambda n, c, up: build_out_residual(n, c), "nc"),
    "hidden_phi": (lambda n, c, up: build_hidden_phi(n, c), "nc"),
    "out_phi": (lambda n, c, up: build_out_phi(n, c), "nc"),
    "z_combine": (lambda n, c, up: build_z_combine(n, c), "nc"),
    "z_prox_val": (lambda n, c, up: build_z_prox_val(n, c), "nc"),
    "zl_fista": (build_zl_fista, "nc_steps"),
    "bp_out_grads": (build_bp_out_grads, "nab"),
    "bp_hidden_grads": (build_bp_hidden_grads, "nab"),
    "xent_loss": (build_xent_loss, "nc"),
}
