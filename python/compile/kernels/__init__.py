# L1: Pallas kernels for the dense compute hot-spots of the community-based
# ADMM trainer. `ref.py` holds the pure-jnp oracles the kernels are tested
# against (pytest + hypothesis).
from .matmul_epilogue import matmul
from .softmax_xent import softmax_xent

__all__ = ["matmul", "softmax_xent"]
