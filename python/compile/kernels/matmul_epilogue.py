"""Tiled matmul with fused epilogue — the L1 compute kernel.

Every dense product in the ADMM subproblems (`S@W`, `H@W+c`, `Sᵀ@R`,
`R@Wᵀ`, ...) funnels through this kernel, so the pre-activation tensor of a
GCN layer never round-trips to HBM: the bias (the paper's cross-community
aggregate `c = Σ_r p_{l,r→m}`) and the ReLU are applied inside the same
grid step that finishes the K-reduction.

TPU adaptation (DESIGN.md §Hardware-Adaptation): (bm, bk, bn) blocks are
sized for VMEM with 128-lane tiles feeding the MXU; the K-grid dimension is
the innermost (sequential) axis so the f32 accumulator lives in the output
block across K-steps. Lowered with ``interpret=True`` — the CPU PJRT plugin
cannot execute Mosaic custom-calls; on-TPU behaviour is estimated
structurally (DESIGN.md §Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 128


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _mm_kernel(x_ref, w_ref, o_ref, *, k_tiles: int, relu: bool):
    """Grid = (m_tiles, n_tiles, k_tiles); K innermost/sequential."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    if relu:

        @pl.when(pl.program_id(2) == k_tiles - 1)
        def _epilogue():
            o_ref[...] = jnp.maximum(o_ref[...], 0.0)


def _mm_bias_kernel(x_ref, w_ref, c_ref, o_ref, *, k_tiles: int, relu: bool):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == k_tiles - 1)
    def _epilogue():
        r = o_ref[...] + c_ref[...]
        if relu:
            r = jnp.maximum(r, 0.0)
        o_ref[...] = r


def matmul(x, w, bias=None, relu=False, use_pallas=True, tile=DEFAULT_TILE):
    """``epilogue(x @ w + bias)`` with epilogue = ReLU or identity.

    x: (M, K), w: (K, N), bias: None or (M, N). Shapes need not be tile
    multiples — inputs are zero-padded (zero rows/cols are inert for both
    the product and the ReLU) and the result sliced back.

    ``use_pallas=False`` selects the plain-XLA lowering of the identical
    math; artifact configs use it to A/B the kernel against XLA's own
    fusion on CPU (the bench in EXPERIMENTS.md §Perf).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"matmul: {x.shape} @ {w.shape}"
    if bias is not None:
        assert bias.shape == (m, n), f"bias {bias.shape} != {(m, n)}"

    if not use_pallas:
        r = jnp.dot(x, w, preferred_element_type=jnp.float32)
        if bias is not None:
            r = r + bias
        return jnp.maximum(r, 0.0) if relu else r

    bm = min(tile, _ceil_to(m, 8))
    bn = min(tile, _ceil_to(n, 8))
    bk = min(tile, _ceil_to(k, 8))
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    grid = (mp // bm, np_ // bn, kp // bk)

    if bias is None:
        kernel = functools.partial(_mm_kernel, k_tiles=grid[2], relu=relu)
        out = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
                pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
            out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
            interpret=True,
        )(xp, wp)
    else:
        cp = jnp.pad(bias, ((0, mp - m), (0, np_ - n)))
        kernel = functools.partial(_mm_bias_kernel, k_tiles=grid[2], relu=relu)
        out = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
                pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
                pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
            out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
            interpret=True,
        )(xp, wp, cp)

    return out[:m, :n]


def vmem_bytes(tile=DEFAULT_TILE) -> int:
    """Estimated VMEM footprint of one grid step (f32): x, w, bias, out
    blocks. Used by the §Perf structural analysis."""
    return 4 * tile * tile * 4
