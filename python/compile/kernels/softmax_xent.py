"""Fused masked softmax cross-entropy (loss + gradient) — L1 kernel.

The risk term `R(Z_L, Y)` of Problem 1 and its gradient, which is the inner
step of the FISTA solve for the `Z_{L,m}` subproblem (paper eq. 7) and the
loss head of the backprop baselines. One pass per row-block computes the
numerically-stabilised log-softmax, the masked mean loss contribution and
the gradient `(softmax(z) − y) ⊙ mask / denom` without materialising the
probability matrix in HBM.

`denom` is an explicit scalar input (not `sum(mask)`) so that per-community
invocations normalise by the *global* labeled-node count — keeping the sum
of community losses equal to the serial loss (DESIGN.md §4 invariant 4).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_TILE = 128
NEG_INF = -1e30


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _xent_kernel(lg_ref, y_ref, mk_ref, dn_ref, loss_ref, grad_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        loss_ref[...] = jnp.zeros_like(loss_ref)

    lg = lg_ref[...]
    y = y_ref[...]
    mask = mk_ref[...]  # (bm, 1)
    denom = dn_ref[0, 0]

    row_max = jnp.max(lg, axis=1, keepdims=True)
    e = jnp.exp(lg - row_max)
    s = jnp.sum(e, axis=1, keepdims=True)
    p = e / s
    lse = jnp.log(s) + row_max  # (bm, 1)

    # loss_i = mask_i * (logsumexp(z_i) - z_i[y_i])
    picked = jnp.sum(y * lg, axis=1, keepdims=True)
    loss_ref[0, 0] += jnp.sum((lse - picked) * mask) / denom
    grad_ref[...] = (p - y) * mask / denom


def softmax_xent(logits, y_onehot, mask, denom, use_pallas=True):
    """Masked mean softmax cross-entropy.

    logits: (N, C) f32; y_onehot: (N, C) f32; mask: (N,) f32 weights
    (0 for unlabeled / padded rows); denom: scalar normaliser.
    Returns (loss (), grad (N, C)).
    """
    n, c = logits.shape
    assert y_onehot.shape == (n, c)
    assert mask.shape == (n,)

    if not use_pallas:
        from . import ref

        return ref.softmax_xent_ref(logits, y_onehot, mask, denom)

    bm = min(ROW_TILE, _ceil_to(n, 8))
    np_ = _ceil_to(n, bm)
    # Lane-pad the class dimension; padded logits at -inf contribute
    # exp(-inf)=0 to the softmax and 0 to the loss (y is zero-padded).
    cp = _ceil_to(c, ROW_TILE)
    lg = jnp.pad(logits, ((0, np_ - n), (0, cp - c)), constant_values=NEG_INF)
    y = jnp.pad(y_onehot, ((0, np_ - n), (0, cp - c)))
    mk = jnp.pad(mask, (0, np_ - n)).reshape(np_, 1)
    dn = jnp.asarray(denom, jnp.float32).reshape(1, 1)

    grid = (np_ // bm,)
    loss, grad = pl.pallas_call(
        _xent_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, cp), lambda i: (i, 0)),
            pl.BlockSpec((bm, cp), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((bm, cp), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((np_, cp), jnp.float32),
        ],
        interpret=True,
    )(lg, y, mk, dn)

    return loss[0, 0], grad[:n, :c]
