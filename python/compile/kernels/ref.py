"""Pure-jnp correctness oracles for the L1 Pallas kernels.

Deliberately written in the most obvious way possible — these definitions
ARE the spec. pytest + hypothesis assert `assert_allclose(kernel, ref)`
across shape/value sweeps (python/tests/test_kernels.py).
"""

import jax.numpy as jnp


def matmul_ref(x, w, bias=None, relu=False):
    """epilogue(x @ w + bias) — oracle for kernels.matmul."""
    r = jnp.dot(x, w, preferred_element_type=jnp.float32)
    if bias is not None:
        r = r + bias
    if relu:
        r = jnp.maximum(r, 0.0)
    return r


def relu(x):
    return jnp.maximum(x, 0.0)


def relu_grad_mask(pre):
    """ReLU subgradient with f'(0) := 0 (keeps zero-padded rows inert)."""
    return (pre > 0.0).astype(jnp.float32)


def softmax_xent_ref(logits, y_onehot, mask, denom):
    """Masked mean softmax cross-entropy — oracle for kernels.softmax_xent.

    Returns (loss, grad): loss = sum_i mask_i * CE_i / denom,
    grad = (softmax(logits) - y) * mask[:, None] / denom.
    """
    denom = jnp.asarray(denom, jnp.float32)
    row_max = jnp.max(logits, axis=1, keepdims=True)
    e = jnp.exp(logits - row_max)
    s = jnp.sum(e, axis=1, keepdims=True)
    p = e / s
    lse = jnp.log(s) + row_max
    picked = jnp.sum(y_onehot * logits, axis=1, keepdims=True)
    loss = jnp.sum((lse - picked) * mask[:, None]) / denom
    grad = (p - y_onehot) * mask[:, None] / denom
    return loss, grad
