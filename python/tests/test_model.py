"""L2 entry-point tests: each ADMM subproblem graph against independent
numpy math / jax autodiff, plus composition tests that drive the artifact
pieces exactly the way the Rust coordinator does."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

RNG = np.random.default_rng(42)


def arr(*shape, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, jnp.float32)


# --------------------------------------------------------------------------
# Matmul primitives
# --------------------------------------------------------------------------


def test_mm_primitives():
    n, a, b = 30, 12, 9
    x, w, y = arr(n, a), arr(a, b), arr(n, b)
    (nn,) = model.build_mm_nn(n, a, b)[0](x, w)
    np.testing.assert_allclose(nn, x @ w, rtol=1e-4, atol=1e-5)
    (tn,) = model.build_mm_tn(n, a, b)[0](x, y)
    np.testing.assert_allclose(tn, x.T @ y, rtol=1e-4, atol=1e-5)
    (bt,) = model.build_mm_bt(n, a, b)[0](y, w)
    np.testing.assert_allclose(bt, y @ w.T, rtol=1e-4, atol=1e-5)
    (fr,) = model.build_fwd_relu(n, a, b)[0](x, w)
    np.testing.assert_allclose(fr, jnp.maximum(x @ w, 0.0), rtol=1e-4, atol=1e-5)


# --------------------------------------------------------------------------
# Residual entries: values and gradients (against autodiff)
# --------------------------------------------------------------------------


def test_hidden_residual_is_grad_of_value():
    n, c = 25, 7
    fn, _ = model.build_hidden_residual(n, c)
    pre, zt = arr(n, c), arr(n, c)
    nu = jnp.float32(0.37)
    val, r = fn(pre, zt, nu)

    def val_of(pre_):
        d = jnp.maximum(pre_, 0.0) - zt
        return 0.5 * nu * jnp.sum(d * d)

    np.testing.assert_allclose(float(val), float(val_of(pre)), rtol=1e-5)
    r_ad = jax.grad(val_of)(pre)
    np.testing.assert_allclose(r, r_ad, rtol=1e-4, atol=1e-5)
    # Value-only entry agrees.
    pv, _ = model.build_hidden_phi(n, c)
    np.testing.assert_allclose(float(pv(pre, zt, nu)[0]), float(val), rtol=1e-6)


def test_out_residual_is_grad_of_value():
    n, c = 21, 5
    fn, _ = model.build_out_residual(n, c)
    pre, zt, u = arr(n, c), arr(n, c), arr(n, c)
    rho = jnp.float32(0.01)
    val, r = fn(pre, zt, u, rho)

    def val_of(pre_):
        d = zt - pre_
        return jnp.sum(u * d) + 0.5 * rho * jnp.sum(d * d)

    np.testing.assert_allclose(float(val), float(val_of(pre)), rtol=1e-4)
    r_ad = jax.grad(val_of)(pre)
    np.testing.assert_allclose(r, r_ad, rtol=1e-4, atol=1e-5)
    pv, _ = model.build_out_phi(n, c)
    np.testing.assert_allclose(float(pv(pre, zt, u, rho)[0]), float(val), rtol=1e-5)


def test_w_gradient_composition_matches_autodiff():
    # gW_l (l<L) assembled the coordinator's way:
    #   V = Z_{l-1} W; pre = Ã V; (phi, R) = hidden_residual;
    #   gW = Z_{l-1}ᵀ (Ã R)
    # must equal d/dW [ ν/2 || f(Ã Z W) − Z_l ||² ].
    n, a, b = 20, 8, 6
    adj = np.triu(RNG.random((n, n)) < 0.2, 1)
    a_np = (adj + adj.T).astype(np.float32) + np.eye(n, dtype=np.float32)
    at = jnp.asarray(a_np)
    zprev, zl, w = arr(n, a), arr(n, b), arr(a, b)
    nu = jnp.float32(0.3)

    def phi_of(w_):
        act = jnp.maximum(at @ zprev @ w_, 0.0)
        return 0.5 * nu * jnp.sum((act - zl) ** 2)

    gw_ad = jax.grad(phi_of)(w)

    (v,) = model.build_mm_nn(n, a, b)[0](zprev, w)
    pre = at @ v  # SpMM (rust)
    phi, r = model.build_hidden_residual(n, b)[0](pre, zl, nu)
    ar = at @ r  # SpMM with Ãᵀ = Ã (rust)
    (gw,) = model.build_mm_tn(n, a, b)[0](zprev, ar)
    np.testing.assert_allclose(float(phi), float(phi_of(w)), rtol=1e-5)
    np.testing.assert_allclose(gw, gw_ad, rtol=1e-4, atol=1e-5)


def test_z_gradient_composition_matches_autodiff():
    # The eq.-6 coupling gradient wrt Z_{L-1}:
    #   d/dZ [ <U, Zt − Ã Z W> + ρ/2||Zt − Ã Z W||² ] = Ãᵀ R Wᵀ
    # assembled as (Ã R) Wᵀ via mm_bt.
    n, a, b = 18, 7, 4
    adj = np.triu(RNG.random((n, n)) < 0.25, 1)
    a_np = (adj + adj.T).astype(np.float32) + np.eye(n, dtype=np.float32)
    at = jnp.asarray(a_np)
    z, zt, u, w = arr(n, a), arr(n, b), arr(n, b), arr(a, b)
    rho = jnp.float32(0.05)

    def val_of(z_):
        d = zt - at @ z_ @ w
        return jnp.sum(u * d) + 0.5 * rho * jnp.sum(d * d)

    gz_ad = jax.grad(val_of)(z)

    (v,) = model.build_mm_nn(n, a, b)[0](z, w)
    pre = at @ v
    val, r = model.build_out_residual(n, b)[0](pre, zt, u, rho)
    ar = at @ r
    (gz,) = model.build_mm_bt(n, a, b)[0](ar, w)
    np.testing.assert_allclose(float(val), float(val_of(z)), rtol=1e-4)
    np.testing.assert_allclose(gz, gz_ad, rtol=1e-4, atol=1e-5)


def test_z_combine_step_prox_and_gnorm():
    n, c = 14, 6
    fn, _ = model.build_z_combine(n, c)
    z, pin, gsum = arr(n, c), arr(n, c), arr(n, c)
    nu, theta = jnp.float32(0.9), jnp.float32(4.0)
    znew, val, gsq = fn(z, pin, gsum, nu, theta)
    fpin = np.maximum(np.asarray(pin), 0.0)
    d = np.asarray(z) - fpin
    g = 0.9 * d + np.asarray(gsum)
    np.testing.assert_allclose(float(val), 0.5 * 0.9 * np.sum(d * d), rtol=1e-5)
    np.testing.assert_allclose(float(gsq), np.sum(g * g), rtol=1e-5)
    np.testing.assert_allclose(znew, np.asarray(z) - g / 4.0, rtol=1e-5, atol=1e-6)
    pv, _ = model.build_z_prox_val(n, c)
    np.testing.assert_allclose(float(pv(z, pin, nu)[0]), float(val), rtol=1e-6)


# --------------------------------------------------------------------------
# Z_L FISTA
# --------------------------------------------------------------------------


def test_zl_fista_decreases_objective_and_beats_start():
    n, c = 40, 5
    steps = 15
    fn, _ = model.build_zl_fista(n, c, steps=steps)
    q, u = arr(n, c), arr(n, c, scale=0.1)
    labels = RNG.integers(0, c, n)
    y = jnp.eye(c, dtype=jnp.float32)[labels]
    mask = jnp.asarray(RNG.random(n) < 0.5, jnp.float32)
    denom = jnp.float32(max(float(mask.sum()), 1.0))
    rho = jnp.float32(0.1)
    z0 = q  # warm start at Q

    def objective(z):
        from compile.kernels.ref import softmax_xent_ref

        loss, _ = softmax_xent_ref(z, y, mask, denom)
        return float(loss + jnp.sum(u * (z - q)) + 0.5 * rho * jnp.sum((z - q) ** 2))

    z_new, risk = fn(q, u, y, mask, z0, rho, denom)
    assert objective(np.asarray(z_new)) < objective(np.asarray(z0)) + 1e-6
    assert np.isfinite(float(risk))
    # More steps → at least as good.
    fn2, _ = model.build_zl_fista(n, c, steps=steps * 3)
    z_more, _ = fn2(q, u, y, mask, z0, rho, denom)
    assert objective(np.asarray(z_more)) <= objective(np.asarray(z_new)) + 1e-5


def test_zl_fista_converges_to_stationary_point():
    n, c = 20, 4
    fn, _ = model.build_zl_fista(n, c, steps=200)
    q = arr(n, c)
    u = arr(n, c, scale=0.05)
    labels = RNG.integers(0, c, n)
    y = jnp.eye(c, dtype=jnp.float32)[labels]
    mask = jnp.ones(n, jnp.float32)
    denom = jnp.float32(n)
    rho = jnp.float32(0.5)
    z, _ = fn(q, u, y, mask, q, rho, denom)

    from compile.kernels.ref import softmax_xent_ref

    _, g = softmax_xent_ref(z, y, mask, denom)
    grad = np.asarray(g + u + rho * (z - q))
    assert np.abs(grad).max() < 1e-3, np.abs(grad).max()


# --------------------------------------------------------------------------
# Backprop baselines
# --------------------------------------------------------------------------


def test_baseline_pieces_compose_to_autodiff_gradient():
    # Full 2-layer GCN gradient assembled from the artifact pieces
    # (+ explicit SpMM) equals jax.grad of the monolithic loss.
    n, f, hdim, c = 22, 9, 7, 4
    adj = RNG.random((n, n)) < 0.15
    adj = np.triu(adj, 1)
    a_np = (adj + adj.T).astype(np.float32)
    deg = a_np.sum(1) + 1.0
    dinv = 1.0 / np.sqrt(deg)
    a_tilde = jnp.asarray(dinv[:, None] * (a_np + np.eye(n, dtype=np.float32)) * dinv[None, :])

    x = arr(n, f)
    w1, w2 = arr(f, hdim, scale=0.3), arr(hdim, c, scale=0.3)
    labels = RNG.integers(0, c, n)
    y = jnp.eye(c, dtype=jnp.float32)[labels]
    mask = jnp.asarray(RNG.random(n) < 0.5, jnp.float32)
    denom = jnp.float32(max(float(mask.sum()), 1.0))

    def loss_of(w1_, w2_):
        z1 = jnp.maximum(a_tilde @ x @ w1_, 0.0)
        logits = a_tilde @ z1 @ w2_
        from compile.kernels.ref import softmax_xent_ref

        return softmax_xent_ref(logits, y, mask, denom)[0]

    gw1_ad, gw2_ad = jax.grad(loss_of, argnums=(0, 1))(w1, w2)

    # Pieces, exactly as the Rust coordinator drives them:
    h0 = a_tilde @ x  # SpMM (rust)
    z1 = model.build_fwd_relu(n, f, hdim)[0](h0, w1)[0]
    h1 = a_tilde @ z1  # SpMM (rust)
    loss, dw2, dh1 = model.build_bp_out_grads(n, hdim, c)[0](h1, w2, y, mask, denom)
    dz1 = a_tilde @ dh1  # SpMM with Ãᵀ = Ã (rust)
    (dw1,) = model.build_bp_hidden_grads(n, f, hdim)[0](h0, w1, dz1)

    np.testing.assert_allclose(float(loss), float(loss_of(w1, w2)), rtol=1e-5)
    np.testing.assert_allclose(dw2, gw2_ad, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(dw1, gw1_ad, rtol=1e-4, atol=1e-5)


def test_entry_registry_complete_and_buildable():
    for name, (builder, kind) in model.ENTRIES.items():
        if kind == "nab":
            fn, args = builder(8, 4, 3, True)
        elif kind == "nc":
            fn, args = builder(8, 3, True)
        elif kind == "nc_steps":
            fn, args = builder(8, 3, 2, True)
        else:
            pytest.fail(f"unknown kind {kind} for {name}")
        out = jax.eval_shape(fn, *args)
        assert len(jax.tree_util.tree_leaves(out)) >= 1, name
