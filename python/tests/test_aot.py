"""AOT driver tests: HLO-text emission + manifest integrity."""

import json
import os
import subprocess
import sys
import tempfile

import pytest

from compile import aot, model


def test_artifact_sig_matches_rust_side():
    # Must mirror config.rs ArtifactSpec::sig().
    assert (
        aot.artifact_sig({"entry": "w_grad", "n": 384, "a": 745, "b": 64})
        == "w_grad__n384_a745_b64"
    )
    assert (
        aot.artifact_sig({"entry": "zl_fista", "n": 256, "c": 8, "steps": 10})
        == "zl_fista__n256_c8_steps10"
    )


def test_lower_one_emits_parseable_hlo_text():
    with tempfile.TemporaryDirectory() as td:
        spec = {"entry": "mm_nn", "n": 16, "a": 4, "b": 3, "pallas": True}
        meta = aot.lower_one(spec, {"use_pallas": True, "fista_steps": 2}, td)
        assert meta["sig"] == "mm_nn__n16_a4_b3"
        assert meta["num_inputs"] == 2
        assert meta["num_outputs"] == 1
        assert meta["input_shapes"] == [[16, 4], [4, 3]]
        text = open(os.path.join(td, meta["file"])).read()
        assert text.startswith("HloModule"), text[:80]
        # return_tuple=True => root is a tuple.
        assert "ROOT" in text


def test_unknown_entry_is_rejected():
    with pytest.raises(KeyError):
        aot.build_fn({"entry": "nope", "n": 8}, {})


def test_main_end_to_end_dedups_and_writes_manifest():
    cfg = {
        "use_pallas": True,
        "fista_steps": 2,
        "artifacts": [
            {"entry": "mm_nn", "n": 16, "a": 4, "b": 3},
            {"entry": "mm_nn", "n": 16, "a": 4, "b": 3},  # duplicate
            {"entry": "xent_loss", "n": 16, "c": 3},
        ],
    }
    with tempfile.TemporaryDirectory() as td:
        cfg_path = os.path.join(td, "cfg.json")
        out_dir = os.path.join(td, "artifacts")
        with open(cfg_path, "w") as f:
            json.dump(cfg, f)
        proc = subprocess.run(
            [sys.executable, "-m", "compile.aot", "--config", cfg_path, "--out", out_dir],
            capture_output=True,
            text=True,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        )
        assert proc.returncode == 0, proc.stderr
        manifest = json.load(open(os.path.join(out_dir, "manifest.json")))
        sigs = [a["sig"] for a in manifest["artifacts"]]
        assert sigs == sorted(set(sigs)) or len(sigs) == len(set(sigs))
        assert len(sigs) == 2  # dedup applied
        for a in manifest["artifacts"]:
            assert os.path.exists(os.path.join(out_dir, a["file"]))
            assert len(a["hlo_sha256"]) == 16


def test_every_registered_entry_lowers():
    # Smoke: tiny shapes, all entries — catches lowering regressions.
    with tempfile.TemporaryDirectory() as td:
        for entry, (_, kind) in model.ENTRIES.items():
            spec = {"entry": entry, "n": 8, "pallas": True}
            if kind == "nab":
                spec.update(a=4, b=3)
            else:
                spec.update(c=3)
            if kind == "nc_steps":
                spec["steps"] = 2
            meta = aot.lower_one(spec, {"use_pallas": True, "fista_steps": 2}, td)
            assert meta["num_outputs"] >= 1, entry
