"""Kernel-vs-oracle tests — the CORE L1 correctness signal.

hypothesis sweeps shapes (including non-tile-multiples and degenerate
dims), value scales and mask patterns; every case asserts the Pallas
kernel matches the pure-jnp oracle in `ref.py` to tight tolerance.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul, softmax_xent
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


def _arr(rng, *shape, scale=1.0):
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)


# ---------------------------------------------------------------------------
# matmul_epilogue
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    m=st.integers(1, 200),
    k=st.integers(1, 200),
    n=st.integers(1, 64),
    relu=st.booleans(),
    with_bias=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, relu, with_bias, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, m, k)
    w = _arr(rng, k, n)
    bias = _arr(rng, m, n) if with_bias else None
    got = matmul(x, w, bias=bias, relu=relu)
    want = ref.matmul_ref(x, w, bias=bias, relu=relu)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(scale=st.sampled_from([1e-3, 1.0, 1e3]), seed=st.integers(0, 2**31 - 1))
def test_matmul_value_scales(scale, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, 50, 70, scale=scale)
    w = _arr(rng, 70, 30, scale=scale)
    got = matmul(x, w)
    want = ref.matmul_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4 * scale * scale)


def test_matmul_exact_tile_multiples():
    rng = np.random.default_rng(7)
    x = _arr(rng, 256, 128)
    w = _arr(rng, 128, 384)
    np.testing.assert_allclose(
        matmul(x, w, relu=True), ref.matmul_ref(x, w, relu=True), rtol=1e-4, atol=1e-4
    )


def test_matmul_padded_rows_stay_zero():
    # Zero rows in, zero rows out — the padding-inertness invariant
    # (DESIGN.md §4 #1).
    rng = np.random.default_rng(8)
    x = np.asarray(rng.normal(size=(40, 30)), np.float32)
    x[25:] = 0.0
    w = _arr(rng, 30, 20)
    out = np.asarray(matmul(jnp.asarray(x), w, relu=True))
    assert np.all(out[25:] == 0.0)


def test_matmul_xla_path_identical():
    rng = np.random.default_rng(9)
    x = _arr(rng, 33, 47)
    w = _arr(rng, 47, 21)
    b = _arr(rng, 33, 21)
    a = matmul(x, w, bias=b, relu=True, use_pallas=True)
    c = matmul(x, w, bias=b, relu=True, use_pallas=False)
    np.testing.assert_allclose(a, c, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# softmax_xent
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    n=st.integers(1, 300),
    c=st.integers(2, 16),
    frac=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_xent_matches_ref(n, c, frac, seed):
    rng = np.random.default_rng(seed)
    logits = _arr(rng, n, c, scale=3.0)
    labels = rng.integers(0, c, size=n)
    y = jnp.eye(c, dtype=jnp.float32)[labels]
    mask = jnp.asarray(rng.random(n) < frac, jnp.float32)
    denom = float(max(mask.sum(), 1.0))
    l1, g1 = softmax_xent(logits, y, mask, denom)
    l2, g2 = ref.softmax_xent_ref(logits, y, mask, denom)
    np.testing.assert_allclose(l1, l2, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(g1, g2, rtol=1e-4, atol=1e-5)


def test_xent_extreme_logits_stable():
    logits = jnp.asarray([[1e4, -1e4, 0.0], [-1e4, 1e4, 0.0]], jnp.float32)
    y = jnp.asarray([[1, 0, 0], [0, 0, 1]], jnp.float32)
    mask = jnp.ones(2, jnp.float32)
    loss, grad = softmax_xent(logits, y, mask, 2.0)
    assert np.isfinite(float(loss))
    assert np.all(np.isfinite(np.asarray(grad)))
    # Row 0 predicted correctly with huge margin: ~0 loss contribution.
    l_ref, _ = ref.softmax_xent_ref(logits, y, mask, 2.0)
    np.testing.assert_allclose(float(loss), float(l_ref), rtol=1e-5, atol=1e-6)


def test_xent_masked_rows_have_zero_grad():
    rng = np.random.default_rng(11)
    logits = _arr(rng, 10, 4)
    y = jnp.eye(4, dtype=jnp.float32)[rng.integers(0, 4, 10)]
    mask = jnp.asarray([1, 0, 1, 0, 0, 0, 1, 0, 0, 0], jnp.float32)
    _, grad = softmax_xent(logits, y, mask, 3.0)
    g = np.asarray(grad)
    for i in range(10):
        if mask[i] == 0:
            assert np.all(g[i] == 0.0)
        else:
            assert np.any(g[i] != 0.0)


def test_xent_gradient_is_gradient_of_loss():
    # Finite differences against the kernel's own loss.
    rng = np.random.default_rng(12)
    n, c = 6, 5
    logits = np.asarray(rng.normal(size=(n, c)), np.float32)
    labels = rng.integers(0, c, n)
    y = jnp.eye(c, dtype=jnp.float32)[labels]
    mask = jnp.ones(n, jnp.float32)
    denom = float(n)
    _, grad = softmax_xent(jnp.asarray(logits), y, mask, denom)
    eps = 1e-3
    for i in range(n):
        for j in range(c):
            lp = logits.copy()
            lp[i, j] += eps
            lm = logits.copy()
            lm[i, j] -= eps
            fp, _ = softmax_xent(jnp.asarray(lp), y, mask, denom)
            fm, _ = softmax_xent(jnp.asarray(lm), y, mask, denom)
            fd = (float(fp) - float(fm)) / (2 * eps)
            assert abs(fd - float(grad[i, j])) < 1e-3, (i, j, fd, float(grad[i, j]))


def test_xent_community_sum_equals_global():
    # Invariant 4 (DESIGN.md): with a global denom, per-community losses
    # and gradients sum/concatenate to the monolithic result.
    rng = np.random.default_rng(13)
    n, c = 90, 7
    logits = _arr(rng, n, c, scale=2.0)
    labels = rng.integers(0, c, n)
    y = jnp.eye(c, dtype=jnp.float32)[labels]
    mask = jnp.asarray(rng.random(n) < 0.4, jnp.float32)
    denom = float(mask.sum())
    lg, gg = softmax_xent(logits, y, mask, denom)
    cuts = [0, 30, 55, n]
    loss_sum = 0.0
    grads = []
    for a, b in zip(cuts[:-1], cuts[1:]):
        l, g = softmax_xent(logits[a:b], y[a:b], mask[a:b], denom)
        loss_sum += float(l)
        grads.append(np.asarray(g))
    np.testing.assert_allclose(loss_sum, float(lg), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.concatenate(grads), np.asarray(gg), rtol=1e-5, atol=1e-6)
